//! Paged KV-cache manager (vLLM-style) owned by the L3 coordinator.
//!
//! Keys/values live in host memory in fixed-size pages drawn from a shared
//! pool; each sequence holds a per-layer page table.  The coordinator
//! gathers a selector's index set into a contiguous staging tile
//! ([B, H, N_sel, d]) which is what the TSA executable consumes — so the
//! bandwidth touched per step scales with N_sel, not context length (the
//! paper's core saving; DESIGN.md §2).
//!
//! Keys are stored *post-RoPE* (positions are baked in at append time by
//! the L2 graph), so gathers need no re-rotation.
//!
//! **Residency (DESIGN.md §2).**  The host pool is the always-fresh
//! source of truth (sparse gathers, selector key reads, probe value
//! reads all stay host-side), while the dense/full-scoring KV can also
//! live in a per-sequence *device mirror* — the same `[nl, H, l_max, d]`
//! tiles packed into one `PjRtBuffer`, tracked by [`DevKvMirror`] and
//! owned by the engine's `runtime::DeviceArena`.  `export_dense`/`gather`
//! are the host-staged implementations behind that interface and remain
//! the parity oracle (`EngineConfig::device_decode_kv = false`) and the
//! fallback for pre-device artifact sets.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, Result};

use crate::runtime::ArenaHandle;

// ---------------------------------------------------------------------
// quantized residency (DESIGN.md §Quantized-Residency)

/// Host KV residency precision (`EngineConfig::kv_quant`).  `Int8` stores
/// the page pool, swap-tier snapshots, and prefix-cache snapshots as
/// per-(head, position) scaled int8 rows — `d + 4` bytes per resident row
/// instead of `4·d` (`kv_bytes::row_bytes`) — and dequantizes into the
/// existing f32 staging paths, so every surface above the pool is
/// unchanged.  The accuracy impact is bounded by `theory::quant_delta_bound`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvQuant {
    /// f32 pages and snapshots (the pre-quantization behavior; default).
    Off,
    /// Per-row scaled int8: one power-of-two f32 scale per `d`-length
    /// (head, position) row plus an i8 payload.
    Int8,
}

impl KvQuant {
    pub fn parse(s: &str) -> Option<KvQuant> {
        match s {
            "off" | "f32" => Some(KvQuant::Off),
            "int8" => Some(KvQuant::Int8),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KvQuant::Off => "off",
            KvQuant::Int8 => "int8",
        }
    }
}

/// Smallest power of two `s` with `127·s ≥ max_abs`, clamped up to
/// `f32::MIN_POSITIVE` so denormal rows still quantize with exact
/// arithmetic.  All-zero (or all-non-finite) rows get scale `0.0` and an
/// all-zero payload.
///
/// The power-of-two restriction is what makes the quantizer *exact*
/// arithmetic end to end: `x / s` is a pure exponent shift, `round` is
/// exact, and `q · s` with `|q| ≤ 127` (7 mantissa bits) is exactly
/// representable — so the round-trip error is precisely
/// `|x − round(x/s)·s| ≤ s/2`, and requantizing a dequantized row is
/// bitwise lossless (snapshots round-trip exactly; see
/// DESIGN.md §Quantized-Residency).
pub fn quant_scale(max_abs: f32) -> f32 {
    if !max_abs.is_finite() || max_abs == 0.0 {
        return 0.0;
    }
    let target = max_abs / 127.0;
    let mut s = target.log2().ceil().exp2();
    if !s.is_finite() || s <= 0.0 {
        s = f32::MIN_POSITIVE;
    }
    // log2/exp2 float fuzz guard: land on the exact smallest power of two
    while s < target {
        s *= 2.0;
    }
    while s * 0.5 >= target && s * 0.5 > 0.0 {
        s *= 0.5;
    }
    s.max(f32::MIN_POSITIVE)
}

/// Quantize one `d`-length f32 row into `out`, returning the
/// power-of-two scale.  Non-finite elements are ignored by the max-abs
/// scan (NaN quantizes to 0, ±inf saturates to ±127), so one poisoned
/// element cannot zero out its neighbors through an infinite scale.
pub fn quantize_row(src: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), out.len());
    let mut max_abs = 0f32;
    for &x in src {
        let a = x.abs();
        if a.is_finite() && a > max_abs {
            max_abs = a;
        }
    }
    let s = quant_scale(max_abs);
    if s == 0.0 {
        out.fill(0);
        return 0.0;
    }
    for (o, &x) in out.iter_mut().zip(src) {
        // saturating float→int cast (NaN → 0 by Rust `as` semantics)
        *o = (x / s).round().clamp(-127.0, 127.0) as i8;
    }
    s
}

/// Dequantize one i8 row back to f32 (exact: power-of-two scale × 7-bit
/// integer).
pub fn dequantize_row(src: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(src.len(), out.len());
    for (o, &q) in out.iter_mut().zip(src) {
        *o = q as f32 * scale;
    }
}

/// `dequantize(quantize(row))` in place — the canonicalization the
/// engine applies to fresh K/V rows *before* they reach the device
/// mirrors, the host pool, or the selector under `KvQuant::Int8`, so all
/// three see identical floats and the pool's own quantization of those
/// floats is a lossless no-op.
pub fn canonicalize_row(row: &mut [f32]) {
    let mut stack = [0i8; 256];
    if row.len() <= stack.len() {
        let q = &mut stack[..row.len()];
        let s = quantize_row(row, q);
        dequantize_row(q, s, row);
    } else {
        let mut q = vec![0i8; row.len()];
        let s = quantize_row(row, &mut q);
        dequantize_row(&q, s, row);
    }
}

/// One quantized K or V page: the int8 twin of a `PagePool` f32 page.
/// `data` is the page's `[n_heads, page_len, d]` i8 payload (the same
/// row layout as the f32 pages — `PagePool::row` offsets apply
/// unchanged) and `scales` holds one power-of-two f32 scale per
/// (head, slot) row.  Scales are per *row* rather than per whole page
/// because pages fill incrementally (decode appends one slot at a time);
/// a page-wide scale would force requantizing stored history whenever a
/// new outlier row lands (DESIGN.md §Quantized-Residency).
#[derive(Clone)]
pub struct QuantPage {
    /// `n_heads · page_len` per-row scales.
    scales: Box<[f32]>,
    /// `n_heads · page_len · d` i8 payload.
    data: Box<[i8]>,
}

/// Quantized twin of a flat `[rows, d]` f32 buffer: per-row power-of-two
/// scales + i8 payload — the storage behind `SwapTier` / `PrefixCache`
/// host snapshots under `KvQuant::Int8`.
#[derive(Clone)]
pub struct QuantBuf {
    d: usize,
    scales: Vec<f32>,
    data: Vec<i8>,
}

impl QuantBuf {
    /// Quantize `src` (length a multiple of `d`) row by row.
    pub fn quantize(src: &[f32], d: usize) -> QuantBuf {
        debug_assert_eq!(src.len() % d, 0);
        let rows = src.len() / d;
        let mut scales = vec![0f32; rows];
        let mut data = vec![0i8; src.len()];
        for r in 0..rows {
            scales[r] =
                quantize_row(&src[r * d..(r + 1) * d], &mut data[r * d..(r + 1) * d]);
        }
        QuantBuf { d, scales, data }
    }

    /// Dequantize rows `[start_row, start_row + rows)` into `out`.
    pub fn dequantize_range(&self, start_row: usize, rows: usize, out: &mut [f32]) {
        let d = self.d;
        for i in 0..rows {
            let r = start_row + i;
            dequantize_row(
                &self.data[r * d..(r + 1) * d],
                self.scales[r],
                &mut out[i * d..(i + 1) * d],
            );
        }
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.data.len()];
        self.dequantize_range(0, self.scales.len(), &mut out);
        out
    }
}

/// A host KV snapshot payload in either residency precision.  `SwapTier`
/// and `PrefixCache` store one per K and one per V buffer; the f32
/// surfaces (`stash`/`take`, `insert`/`entry_row_into`) are unchanged —
/// quantization happens on the way in, dequantization on the way out.
/// Because the engine canonicalizes rows before they reach any store
/// under `Int8`, the requantization here is bitwise lossless.
#[derive(Clone)]
enum HostKv {
    F32(Vec<f32>),
    Int8(QuantBuf),
}

impl HostKv {
    fn from_f32(buf: Vec<f32>, d: usize, quant: KvQuant) -> HostKv {
        match quant {
            KvQuant::Off => HostKv::F32(buf),
            KvQuant::Int8 => HostKv::Int8(QuantBuf::quantize(&buf, d)),
        }
    }

    fn into_f32(self) -> Vec<f32> {
        match self {
            HostKv::F32(b) => b,
            HostKv::Int8(q) => q.dequantize(),
        }
    }
}

/// Where a sequence's dense-path KV is staged from on this step
/// (`Engine::decode_kv_residency`): `Device` reads the per-sequence
/// mirror buffer in place; `HostStaged` re-uploads the context tile via
/// `export_dense` every dense/retrieval call (bandwidth ∝ L — the class
/// of overhead the device mode removes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidencyMode {
    Device,
    HostStaged,
}

/// Per-sequence device KV residency record, living in one of three homes
/// (DESIGN.md §2):
///
/// * `Solo` — a whole `[2, n_layers, H, lb, d]` K|V tile in its own flat
///   device buffer; `handle` indexes the engine's `DeviceArena` (PJRT
///   buffers are not `Send`; the sequence carries only this handle).
///   The per-sequence dispatch path (`layer_step_dense_dev` /
///   `kv_append_dev`), kept as the batched path's parity oracle and the
///   fallback for pre-batch artifact sets.
/// * `Slot` — slot `slot` of a stacked whole-tile group buffer tracked
///   by the engine's `runtime::SlotGroups` under group id `group`, so
///   dense reads and appends batch across the group's members in one
///   dispatch (`layer_step_dense_dev_batch` / `kv_append_dev_batch`) —
///   decode dispatches per step are O(#groups), not O(#sequences).
/// * `Paged` — `blocks` physical block ids (from the engine's
///   [`BlockAllocator`]) into the shared
///   `[2, n_layers, max_blocks, H, block, d]` device pool, gathered
///   in-graph through a block-table operand
///   (`layer_step_dense_dev_paged` / `kv_append_dev_paged`).  The
///   sequence grows block-at-a-time with zero re-home copies and its
///   device footprint is ⌈len/block⌉ blocks, not a whole padded tile.
///
/// For the tile homes `lb` is the compiled l_max bucket; for `Paged` the
/// capacity is `blocks.len() · block` and grows with the table.  `len`
/// is the valid row count.  Invariant: while live, `len == cache.len()`
/// and `len < capacity` — the engine appends every decode step and
/// drops, re-buckets, or extends the residency instead of letting it go
/// stale.
#[derive(Clone, Debug)]
pub enum DevKvMirror {
    Solo { handle: ArenaHandle, lb: usize, len: usize },
    Slot { group: usize, slot: usize, lb: usize, len: usize },
    Paged { blocks: Vec<usize>, block: usize, len: usize },
}

impl DevKvMirror {
    /// Current row capacity: the compiled bucket for the tile homes, the
    /// table's block span for the paged home.
    pub fn lb(&self) -> usize {
        match self {
            DevKvMirror::Solo { lb, .. } | DevKvMirror::Slot { lb, .. } => *lb,
            DevKvMirror::Paged { blocks, block, .. } => blocks.len() * block,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DevKvMirror::Solo { len, .. }
            | DevKvMirror::Slot { len, .. }
            | DevKvMirror::Paged { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn set_len(&mut self, new_len: usize) {
        match self {
            DevKvMirror::Solo { len, .. }
            | DevKvMirror::Slot { len, .. }
            | DevKvMirror::Paged { len, .. } => *len = new_len,
        }
    }
}

/// Refcounted allocator for the paged device KV pool — the host-side
/// twin of the `[2, nl, max_blocks, H, block, d]` pool buffer the engine
/// keeps in its `DeviceArena`.  Hands out physical block ids; a block
/// returns to the free list when its last holder releases it.
/// Refcounts (rather than a plain free list) so block *sharing* — an
/// in-device prefix cache seeding many sequences from one block run — is
/// a `retain` away, mirroring `PagePool`'s role on the host side.
// Clone lets the schedule explorer (`analysis::sched`) fork allocator
// states in the loom_* lane; the engine never clones a live allocator.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    /// Holder count per physical block; 0 = free.
    refs: Vec<u32>,
    /// Free ids, popped LIFO so fresh sequences reuse warm blocks.
    free: Vec<usize>,
}

impl BlockAllocator {
    pub fn new(capacity: usize) -> Self {
        BlockAllocator {
            refs: vec![0; capacity],
            // Reversed so ids hand out in ascending order initially
            // (deterministic pool layouts in tests and traces).
            free: (0..capacity).rev().collect(),
        }
    }

    /// Total physical blocks in the pool (`max_blocks`).
    pub fn capacity(&self) -> usize {
        self.refs.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn in_use(&self) -> usize {
        self.refs.len() - self.free.len()
    }

    pub fn ref_count(&self, id: usize) -> u32 {
        self.refs[id]
    }

    /// Claim a free block (refcount 0 → 1).  `None` when the pool is
    /// exhausted — the engine then falls back to the tile path for the
    /// requesting sequence instead of evicting a neighbor.
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refs[id], 0, "free list held a live block");
        self.refs[id] = 1;
        Some(id)
    }

    /// Add a holder to a live block (block sharing).
    pub fn retain(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "retain of free block {id}");
        self.refs[id] += 1;
    }

    /// Drop one holder; the block frees when the count reaches 0.
    pub fn release(&mut self, id: usize) {
        debug_assert!(self.refs[id] > 0, "double free of block {id}");
        self.refs[id] -= 1;
        if self.refs[id] == 0 {
            self.free.push(id);
        }
    }
}

/// Shared page pool.  One page stores `n_heads * page_len * head_dim` f32
/// for keys and the same for values (a K page and V page are allocated as
/// one unit to halve page-table overhead).
///
/// The pool is optionally capped (`EngineConfig::max_kv_pages`): `alloc`
/// fails instead of growing past the cap, so a burst of long prompts
/// surfaces as a scheduling decision (`BatchPolicy::admit` holds requests
/// in the waiting queue until pages free up) rather than a host OOM.
pub struct PagePool {
    pub n_heads: usize,
    pub head_dim: usize,
    pub page_len: usize,
    /// Residency precision of this pool's pages (`EngineConfig::kv_quant`):
    /// `Off` uses `k_pages`/`v_pages`, `Int8` uses `qk_pages`/`qv_pages`.
    quant: KvQuant,
    /// Hard cap on allocated pages; 0 = unbounded (the pre-cap behavior).
    max_pages: usize,
    k_pages: Vec<Box<[f32]>>,
    v_pages: Vec<Box<[f32]>>,
    qk_pages: Vec<QuantPage>,
    qv_pages: Vec<QuantPage>,
    /// `d`-length rows dequantized by read paths since construction
    /// (gather / export / `key_into` staging; mirrored into
    /// `StepStats::dequant_rows`).  Relaxed atomic so `&self` read paths
    /// running on planner threads can count without a lock.
    dequant_rows: AtomicU64,
    free: Vec<usize>,
}

// Clone lets the schedule explorer (`analysis::sched`) fork pool states
// in the loom_* accounting model; the engine never clones a live pool.
// Manual because `AtomicU64` is not `Clone`.
impl Clone for PagePool {
    fn clone(&self) -> Self {
        PagePool {
            n_heads: self.n_heads,
            head_dim: self.head_dim,
            page_len: self.page_len,
            quant: self.quant,
            max_pages: self.max_pages,
            k_pages: self.k_pages.clone(),
            v_pages: self.v_pages.clone(),
            qk_pages: self.qk_pages.clone(),
            qv_pages: self.qv_pages.clone(),
            dequant_rows: AtomicU64::new(self.dequant_rows.load(Ordering::Relaxed)),
            free: self.free.clone(),
        }
    }
}

impl PagePool {
    pub fn new(n_heads: usize, head_dim: usize, page_len: usize) -> Self {
        Self::with_limit(n_heads, head_dim, page_len, 0)
    }

    pub fn with_limit(
        n_heads: usize,
        head_dim: usize,
        page_len: usize,
        max_pages: usize,
    ) -> Self {
        Self::with_limit_quant(n_heads, head_dim, page_len, max_pages, KvQuant::Off)
    }

    pub fn with_limit_quant(
        n_heads: usize,
        head_dim: usize,
        page_len: usize,
        max_pages: usize,
        quant: KvQuant,
    ) -> Self {
        PagePool {
            n_heads,
            head_dim,
            page_len,
            quant,
            max_pages,
            k_pages: Vec::new(),
            v_pages: Vec::new(),
            qk_pages: Vec::new(),
            qv_pages: Vec::new(),
            dequant_rows: AtomicU64::new(0),
            free: Vec::new(),
        }
    }

    fn page_elems(&self) -> usize {
        self.n_heads * self.page_len * self.head_dim
    }

    /// Residency precision of this pool's pages.
    pub fn quant(&self) -> KvQuant {
        self.quant
    }

    /// Lifetime count of `d`-length rows dequantized by read paths
    /// (always 0 with `kv_quant = off`).
    pub fn dequant_rows(&self) -> u64 {
        self.dequant_rows.load(Ordering::Relaxed)
    }

    pub fn allocated_pages(&self) -> usize {
        match self.quant {
            KvQuant::Off => self.k_pages.len(),
            KvQuant::Int8 => self.qk_pages.len(),
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn in_use_pages(&self) -> usize {
        self.allocated_pages() - self.free.len()
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    /// Pages that can still be handed out *right now*: free pages plus
    /// growth headroom under the cap (`usize::MAX` when unbounded).
    /// NOTE: this is an occupancy snapshot, not an admission input —
    /// admission gates on the cap minus the worst-case *reservations* of
    /// in-flight sequences (`coordinator::Scheduler::step`), because a
    /// sequence keeps growing into its reservation during decode after
    /// this snapshot is taken.
    pub fn available_pages(&self) -> usize {
        if self.max_pages == 0 {
            usize::MAX
        } else {
            self.max_pages.saturating_sub(self.in_use_pages())
        }
    }

    fn alloc(&mut self) -> Result<usize> {
        if let Some(id) = self.free.pop() {
            return Ok(id);
        }
        if self.max_pages > 0 && self.allocated_pages() >= self.max_pages {
            return Err(anyhow!(
                "KV page pool exhausted: {} pages allocated (max_kv_pages = {}); \
                 admission control should have held this request",
                self.allocated_pages(),
                self.max_pages
            ));
        }
        let n = self.page_elems();
        match self.quant {
            KvQuant::Off => {
                self.k_pages.push(vec![0f32; n].into_boxed_slice());
                self.v_pages.push(vec![0f32; n].into_boxed_slice());
                Ok(self.k_pages.len() - 1)
            }
            KvQuant::Int8 => {
                let rows = self.n_heads * self.page_len;
                let fresh = || QuantPage {
                    scales: vec![0f32; rows].into_boxed_slice(),
                    data: vec![0i8; n].into_boxed_slice(),
                };
                self.qk_pages.push(fresh());
                self.qv_pages.push(fresh());
                Ok(self.qk_pages.len() - 1)
            }
        }
    }

    fn release(&mut self, id: usize) {
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
    }

    /// Row offset of (head, slot) inside a page.
    #[inline]
    fn row(&self, head: usize, slot: usize) -> usize {
        (head * self.page_len + slot) * self.head_dim
    }
}

/// Per-sequence, per-layer paged KV cache.
pub struct SeqKvCache {
    pub n_layers: usize,
    len: usize,
    /// page ids per layer, in position order.
    tables: Vec<Vec<usize>>,
}

impl SeqKvCache {
    pub fn new(n_layers: usize) -> Self {
        SeqKvCache { n_layers, len: 0, tables: vec![Vec::new(); n_layers] }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Append one token's K/V for `layer`. `k`/`v` are `[n_heads * d]`
    /// head-major rows.  The position index is implicit (`self.len` after
    /// the *last* layer's append advances it via `commit_token`).
    pub fn append(
        &mut self,
        pool: &mut PagePool,
        layer: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        let d = pool.head_dim;
        let h = pool.n_heads;
        if k.len() != h * d || v.len() != h * d {
            return Err(anyhow!(
                "append: expected {} floats, got k={} v={}",
                h * d,
                k.len(),
                v.len()
            ));
        }
        let pos = self.len;
        let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
        while self.tables[layer].len() <= pi {
            let id = pool.alloc()?;
            self.tables[layer].push(id);
        }
        let page_id = self.tables[layer][pi];
        match pool.quant {
            KvQuant::Off => {
                for head in 0..h {
                    let off = pool.row(head, slot);
                    pool.k_pages[page_id][off..off + d]
                        .copy_from_slice(&k[head * d..(head + 1) * d]);
                    pool.v_pages[page_id][off..off + d]
                        .copy_from_slice(&v[head * d..(head + 1) * d]);
                }
            }
            KvQuant::Int8 => {
                let pl = pool.page_len;
                for head in 0..h {
                    let off = (head * pl + slot) * d;
                    let r = head * pl + slot;
                    let kp = &mut pool.qk_pages[page_id];
                    kp.scales[r] = quantize_row(
                        &k[head * d..(head + 1) * d],
                        &mut kp.data[off..off + d],
                    );
                    let vp = &mut pool.qv_pages[page_id];
                    vp.scales[r] = quantize_row(
                        &v[head * d..(head + 1) * d],
                        &mut vp.data[off..off + d],
                    );
                }
            }
        }
        Ok(())
    }

    /// Advance the sequence length after all layers appended position
    /// `self.len`.
    pub fn commit_token(&mut self) {
        self.len += 1;
    }

    /// Bulk-load a prefill result: `k`/`v` are `[n_layers, H, L, d]`
    /// row-major with `length` valid positions.
    pub fn load_prefill(
        &mut self,
        pool: &mut PagePool,
        k: &[f32],
        v: &[f32],
        l_max: usize,
        length: usize,
    ) -> Result<()> {
        self.load_prefill_range(pool, k, v, l_max, 0, length)
    }

    /// Slice-based prefill load for chunked prefill (DESIGN.md §6a): copy
    /// only positions `[start, end)` out of a `[n_layers, H, l_max, d]`
    /// prefill result computed over the prompt *prefix* of length ≥ `end`.
    /// Appends are strictly sequential, so `start` must equal the cached
    /// length — earlier chunks must already be loaded.
    pub fn load_prefill_range(
        &mut self,
        pool: &mut PagePool,
        k: &[f32],
        v: &[f32],
        l_max: usize,
        start: usize,
        end: usize,
    ) -> Result<()> {
        let (h, d) = (pool.n_heads, pool.head_dim);
        if k.len() != self.n_layers * h * l_max * d
            || v.len() != self.n_layers * h * l_max * d
        {
            return Err(anyhow!("load_prefill_range: bad k/v size"));
        }
        if start != self.len {
            return Err(anyhow!(
                "load_prefill_range: start {start} != cached length {}",
                self.len
            ));
        }
        if end > l_max {
            return Err(anyhow!(
                "load_prefill_range: end {end} exceeds l_max {l_max}"
            ));
        }
        self.load_rows(pool, k, v, l_max, start, end.saturating_sub(start))
    }

    /// Bulk-load a whole prefill from the device-resident path's packed
    /// state (DESIGN.md §6a): `kv` is the state's leading
    /// `[2, n_layers, H, l_max, d]` segment — the K tile followed by the
    /// V tile in `export_dense` layout — downloaded ONCE at prefill
    /// completion (`Engine::prefill_chunk_dev`), with `length` valid
    /// positions.  The cache must be empty (this path never loads
    /// per-chunk).
    pub fn load_prefill_all(
        &mut self,
        pool: &mut PagePool,
        kv: &[f32],
        l_max: usize,
        length: usize,
    ) -> Result<()> {
        let half = self.n_layers * pool.n_heads * l_max * pool.head_dim;
        if kv.len() != 2 * half {
            return Err(anyhow!("load_prefill_all: bad packed kv size"));
        }
        if !self.is_empty() {
            return Err(anyhow!(
                "load_prefill_all: cache already holds {} positions",
                self.len
            ));
        }
        let (k, v) = kv.split_at(half);
        self.load_prefill_range(pool, k, v, l_max, 0, length)
    }

    /// Append `count` positions of a KV-in chunk-prefill result
    /// (`prefill_extend`, DESIGN.md §6a): `k`/`v` are
    /// `[n_layers, H, chunk_w, d]` *chunk-relative* tiles — tile row 0 is
    /// the cache's current end, so no absolute-position bookkeeping leaks
    /// into the artifact output.
    pub fn load_chunk(
        &mut self,
        pool: &mut PagePool,
        k: &[f32],
        v: &[f32],
        chunk_w: usize,
        count: usize,
    ) -> Result<()> {
        let (h, d) = (pool.n_heads, pool.head_dim);
        if k.len() != self.n_layers * h * chunk_w * d
            || v.len() != self.n_layers * h * chunk_w * d
        {
            return Err(anyhow!("load_chunk: bad k/v size"));
        }
        if count > chunk_w {
            return Err(anyhow!(
                "load_chunk: count {count} exceeds chunk width {chunk_w}"
            ));
        }
        self.load_rows(pool, k, v, chunk_w, 0, count)
    }

    /// Shared bulk-load core: append `count` rows whose tile positions are
    /// `[tile_off, tile_off + count)` in a `[n_layers, H, tile_w, d]`
    /// source tile.  For a fixed (layer, head) the source rows are
    /// contiguous and a head's page rows are contiguous, so the inner
    /// loop is one memcpy per (layer, head, page) run of up to
    /// `page_len·d` floats — the same shape as `export_dense`, replacing
    /// the old one-(pos, layer)-row-at-a-time `append` path.
    ///
    /// On a pool-cap allocation failure the cache length is unchanged;
    /// already-allocated pages stay in the page table (released with the
    /// sequence).
    fn load_rows(
        &mut self,
        pool: &mut PagePool,
        k: &[f32],
        v: &[f32],
        tile_w: usize,
        tile_off: usize,
        count: usize,
    ) -> Result<()> {
        let (h, d) = (pool.n_heads, pool.head_dim);
        let dst_start = self.len;
        let dst_end = dst_start + count;
        for layer in 0..self.n_layers {
            while self.tables[layer].len() * pool.page_len < dst_end {
                let id = pool.alloc()?;
                self.tables[layer].push(id);
            }
        }
        for layer in 0..self.n_layers {
            for head in 0..h {
                let mut done = 0usize;
                while done < count {
                    let pos = dst_start + done;
                    let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
                    let run = (pool.page_len - slot).min(count - done);
                    let page_id = self.tables[layer][pi];
                    let off = pool.row(head, slot);
                    let src =
                        ((layer * h + head) * tile_w + tile_off + done) * d;
                    match pool.quant {
                        KvQuant::Off => {
                            pool.k_pages[page_id][off..off + run * d]
                                .copy_from_slice(&k[src..src + run * d]);
                            pool.v_pages[page_id][off..off + run * d]
                                .copy_from_slice(&v[src..src + run * d]);
                        }
                        KvQuant::Int8 => {
                            // one quantize per d-row of the run (the run's
                            // page rows are contiguous, so `off/d + i` is
                            // the scale index of row i)
                            let kp = &mut pool.qk_pages[page_id];
                            let vp = &mut pool.qv_pages[page_id];
                            for i in 0..run {
                                let ro = off + i * d;
                                let so = src + i * d;
                                kp.scales[ro / d] = quantize_row(
                                    &k[so..so + d],
                                    &mut kp.data[ro..ro + d],
                                );
                                vp.scales[ro / d] = quantize_row(
                                    &v[so..so + d],
                                    &mut vp.data[ro..ro + d],
                                );
                            }
                        }
                    }
                    done += run;
                }
            }
        }
        self.len = dst_end;
        Ok(())
    }

    /// Key row accessor (selectors use this for Quest summaries / DS
    /// channel scoring / similarity ablations).  Borrowed f32 rows only
    /// exist with `kv_quant = off`; quant-proof callers use
    /// [`key_into`](Self::key_into).
    pub fn key<'p>(
        &self,
        pool: &'p PagePool,
        layer: usize,
        head: usize,
        pos: usize,
    ) -> &'p [f32] {
        assert_eq!(
            pool.quant,
            KvQuant::Off,
            "key(): no borrowed f32 rows under int8 residency; use key_into"
        );
        debug_assert!(pos < self.len);
        let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
        let page = &pool.k_pages[self.tables[layer][pi]];
        let off = pool.row(head, slot);
        &page[off..off + pool.head_dim]
    }

    pub fn value<'p>(
        &self,
        pool: &'p PagePool,
        layer: usize,
        head: usize,
        pos: usize,
    ) -> &'p [f32] {
        assert_eq!(
            pool.quant,
            KvQuant::Off,
            "value(): no borrowed f32 rows under int8 residency; use value_into"
        );
        let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
        let page = &pool.v_pages[self.tables[layer][pi]];
        let off = pool.row(head, slot);
        &page[off..off + pool.head_dim]
    }

    /// Copy (dequantizing under `Int8`) the (layer, head, pos) key row
    /// into `out[..d]` — the quant-proof twin of [`key`](Self::key).
    /// Under `Int8` the selector's score pass reads the *quantized* keys
    /// through this path (the resident key sketch); exact-path consumers
    /// get the same canonical floats the device mirrors hold.
    pub fn key_into(
        &self,
        pool: &PagePool,
        layer: usize,
        head: usize,
        pos: usize,
        out: &mut [f32],
    ) {
        debug_assert!(pos < self.len);
        let d = pool.head_dim;
        let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
        let page_id = self.tables[layer][pi];
        let off = pool.row(head, slot);
        match pool.quant {
            KvQuant::Off => {
                out[..d].copy_from_slice(&pool.k_pages[page_id][off..off + d]);
            }
            KvQuant::Int8 => {
                let p = &pool.qk_pages[page_id];
                dequantize_row(&p.data[off..off + d], p.scales[off / d], &mut out[..d]);
                pool.dequant_rows.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Copy (dequantizing under `Int8`) the (layer, head, pos) value row
    /// into `out[..d]` — the quant-proof twin of [`value`](Self::value).
    pub fn value_into(
        &self,
        pool: &PagePool,
        layer: usize,
        head: usize,
        pos: usize,
        out: &mut [f32],
    ) {
        let d = pool.head_dim;
        let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
        let page_id = self.tables[layer][pi];
        let off = pool.row(head, slot);
        match pool.quant {
            KvQuant::Off => {
                out[..d].copy_from_slice(&pool.v_pages[page_id][off..off + d]);
            }
            KvQuant::Int8 => {
                let p = &pool.qv_pages[page_id];
                dequantize_row(&p.data[off..off + d], p.scales[off / d], &mut out[..d]);
                pool.dequant_rows.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Gather `indices` rows of (K, V) for (layer, head) into `out_k` /
    /// `out_v` (each `indices.len() * d` floats) — the hot-path staging
    /// step feeding the TSA executable.
    pub fn gather(
        &self,
        pool: &PagePool,
        layer: usize,
        head: usize,
        indices: &[usize],
        out_k: &mut [f32],
        out_v: &mut [f32],
    ) {
        let d = pool.head_dim;
        debug_assert!(out_k.len() >= indices.len() * d);
        for (i, &pos) in indices.iter().enumerate() {
            let (pi, slot) = (pos / pool.page_len, pos % pool.page_len);
            let page_id = self.tables[layer][pi];
            let off = pool.row(head, slot);
            match pool.quant {
                KvQuant::Off => {
                    out_k[i * d..(i + 1) * d]
                        .copy_from_slice(&pool.k_pages[page_id][off..off + d]);
                    out_v[i * d..(i + 1) * d]
                        .copy_from_slice(&pool.v_pages[page_id][off..off + d]);
                }
                KvQuant::Int8 => {
                    // exact f32 reconstruction happens only here, for the
                    // selected rows — the N_sel-proportional dequant cost
                    // the sketch path is designed around
                    let kp = &pool.qk_pages[page_id];
                    let vp = &pool.qv_pages[page_id];
                    dequantize_row(
                        &kp.data[off..off + d],
                        kp.scales[off / d],
                        &mut out_k[i * d..(i + 1) * d],
                    );
                    dequantize_row(
                        &vp.data[off..off + d],
                        vp.scales[off / d],
                        &mut out_v[i * d..(i + 1) * d],
                    );
                }
            }
        }
        if pool.quant == KvQuant::Int8 {
            pool.dequant_rows
                .fetch_add(2 * indices.len() as u64, Ordering::Relaxed);
        }
    }

    /// Densely export `[H, len, d]` K and V for one layer (retrieval /
    /// dense-baseline path; bandwidth ∝ L by design — this is the cost the
    /// paper's sparsity avoids).
    pub fn export_dense(
        &self,
        pool: &PagePool,
        layer: usize,
        l_max: usize,
        out_k: &mut [f32],
        out_v: &mut [f32],
    ) {
        let (h, d) = (pool.n_heads, pool.head_dim);
        debug_assert!(out_k.len() >= h * l_max * d);
        let n = self.len.min(l_max);
        // Per-(head, page) chunk copies: within a page, a head's rows are
        // contiguous, so the inner loop is one memcpy of up to
        // page_len*d floats (perf log §Perf item 2) — or, under int8
        // residency, one dequant per d-row of the run.
        for head in 0..h {
            let mut pos = 0usize;
            while pos < n {
                let pi = pos / pool.page_len;
                let slot = pos % pool.page_len;
                let run = (pool.page_len - slot).min(n - pos);
                let page_id = self.tables[layer][pi];
                let off = pool.row(head, slot);
                let dst = (head * l_max + pos) * d;
                match pool.quant {
                    KvQuant::Off => {
                        out_k[dst..dst + run * d].copy_from_slice(
                            &pool.k_pages[page_id][off..off + run * d],
                        );
                        out_v[dst..dst + run * d].copy_from_slice(
                            &pool.v_pages[page_id][off..off + run * d],
                        );
                    }
                    KvQuant::Int8 => {
                        let kp = &pool.qk_pages[page_id];
                        let vp = &pool.qv_pages[page_id];
                        for i in 0..run {
                            let ro = off + i * d;
                            let dd = dst + i * d;
                            dequantize_row(
                                &kp.data[ro..ro + d],
                                kp.scales[ro / d],
                                &mut out_k[dd..dd + d],
                            );
                            dequantize_row(
                                &vp.data[ro..ro + d],
                                vp.scales[ro / d],
                                &mut out_v[dd..dd + d],
                            );
                        }
                    }
                }
                pos += run;
            }
        }
        if pool.quant == KvQuant::Int8 {
            pool.dequant_rows
                .fetch_add(2 * (h * n) as u64, Ordering::Relaxed);
        }
    }

    /// Densely export `[n_kv, len, d]` *unexpanded* K and V for one layer
    /// — the staging path for artifacts whose cache input is sized by
    /// `Hkv` (`layer_step_dense`, which re-expands in-graph via
    /// `_repeat_kv`).  The pool stores GQA-expanded `H` rows where the
    /// `H / n_kv` heads of one KV group are bitwise-identical copies, so
    /// kv-head `g`'s row is expanded head `g · (H / n_kv)`.  Sizing
    /// these tiles by the pool's `H` was the latent GQA overrun the
    /// ROADMAP flagged: with `n_kv < H` the old `export_dense` staging
    /// wrote `H` rows into a per-sequence slice sized for `Hkv`.
    /// Degenerates to `export_dense` when `n_kv == n_heads`.
    pub fn export_dense_kv(
        &self,
        pool: &PagePool,
        layer: usize,
        l_max: usize,
        n_kv: usize,
        out_k: &mut [f32],
        out_v: &mut [f32],
    ) {
        let d = pool.head_dim;
        debug_assert_eq!(pool.n_heads % n_kv, 0, "H must be a multiple of Hkv");
        debug_assert!(out_k.len() >= n_kv * l_max * d);
        let rep = pool.n_heads / n_kv;
        let n = self.len.min(l_max);
        for g in 0..n_kv {
            let head = g * rep; // group leader in the expanded pool
            let mut pos = 0usize;
            while pos < n {
                let pi = pos / pool.page_len;
                let slot = pos % pool.page_len;
                let run = (pool.page_len - slot).min(n - pos);
                let page_id = self.tables[layer][pi];
                let off = pool.row(head, slot);
                let dst = (g * l_max + pos) * d;
                match pool.quant {
                    KvQuant::Off => {
                        out_k[dst..dst + run * d].copy_from_slice(
                            &pool.k_pages[page_id][off..off + run * d],
                        );
                        out_v[dst..dst + run * d].copy_from_slice(
                            &pool.v_pages[page_id][off..off + run * d],
                        );
                    }
                    KvQuant::Int8 => {
                        let kp = &pool.qk_pages[page_id];
                        let vp = &pool.qv_pages[page_id];
                        for i in 0..run {
                            let ro = off + i * d;
                            let dd = dst + i * d;
                            dequantize_row(
                                &kp.data[ro..ro + d],
                                kp.scales[ro / d],
                                &mut out_k[dd..dd + d],
                            );
                            dequantize_row(
                                &vp.data[ro..ro + d],
                                vp.scales[ro / d],
                                &mut out_v[dd..dd + d],
                            );
                        }
                    }
                }
                pos += run;
            }
        }
        if pool.quant == KvQuant::Int8 {
            pool.dequant_rows
                .fetch_add(2 * (n_kv * n) as u64, Ordering::Relaxed);
        }
    }

    /// Release all pages back to the pool (sequence finished).
    pub fn release(&mut self, pool: &mut PagePool) {
        for table in &mut self.tables {
            for id in table.drain(..) {
                pool.release(id);
            }
        }
        self.len = 0;
    }

    pub fn pages_held(&self) -> usize {
        self.tables.iter().map(Vec::len).sum()
    }
}

// ---------------------------------------------------------------------
// host swap tier (DESIGN.md §Overload)

/// One suspended sequence's host KV snapshot: `k`/`v` are
/// `[n_layers, tokens, H, d]` row-major — the same position-major entry
/// layout as [`PrefixCache`] snapshots, so restore is one contiguous
/// `H·d` row per (layer, pos).
#[derive(Clone)]
struct SwapEntry {
    id: u64,
    tokens: usize,
    k: HostKv,
    v: HostKv,
}

/// Host-memory swap tier for preempted sequences (the overload
/// subsystem's capacity lever, DESIGN.md §Overload).  When the scheduler
/// suspends a sequence at *host* depth — freeing its `PagePool` pages,
/// not just its device blocks — the exact KV bytes move here and move
/// back bitwise on resume, so a preempted trajectory is
/// indistinguishable from an uninterrupted one.  The budget is counted
/// in blocks of `block` tokens (the same granularity as the paged
/// device pool and the prefix cache); 0 means unbounded.  When a stash
/// would exceed the budget the caller sheds the victim instead
/// (`RejectReason::Preempted`) — the tier never evicts silently,
/// because its contents are the only copy of a live sequence's state.
#[derive(Clone)]
pub struct SwapTier {
    block: usize,
    budget_blocks: usize,
    /// Snapshot residency precision; `Int8` quantizes per `row_d`-length
    /// row on stash and dequantizes on take.  The engine hands this tier
    /// *canonical* (already quantize→dequantize'd) floats under `Int8`,
    /// so the round trip here stays bitwise lossless.
    quant: KvQuant,
    /// Quantization row length (`head_dim`); unused with `quant = Off`.
    row_d: usize,
    entries: Vec<SwapEntry>,
    /// Running Σ of `blocks_for(entry.tokens)` across `entries`, updated
    /// in `stash`/`take`/`discard` so the scheduler's per-victim
    /// `can_stash` feasibility probes are O(1) instead of a full-tier
    /// re-sum per probe (the Σ-recompute survives as a debug assertion
    /// in `resident_blocks`).
    resident: usize,
    /// Lifetime counters (mirrored into `StepStats` by the engine).
    pub stashes: u64,
    pub restores: u64,
    /// High-water mark of `resident_blocks` (the pressure gauge).
    pub peak_blocks: usize,
}

impl SwapTier {
    pub fn new(budget_blocks: usize, block: usize) -> Self {
        Self::with_quant(budget_blocks, block, KvQuant::Off, 1)
    }

    pub fn with_quant(
        budget_blocks: usize,
        block: usize,
        quant: KvQuant,
        row_d: usize,
    ) -> Self {
        SwapTier {
            block: block.max(1),
            budget_blocks,
            quant,
            row_d: row_d.max(1),
            entries: Vec::new(),
            resident: 0,
            stashes: 0,
            restores: 0,
            peak_blocks: 0,
        }
    }

    /// Budget granularity in tokens.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Budget in blocks; 0 = unbounded.
    pub fn budget_blocks(&self) -> usize {
        self.budget_blocks
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block)
    }

    /// Blocks across stashed entries — the budget's occupancy.  O(1):
    /// maintained as a running counter by `stash`/`take`/`discard`; the
    /// old Σ-recompute is kept as a drift assertion.
    pub fn resident_blocks(&self) -> usize {
        debug_assert_eq!(
            self.resident,
            self.entries
                .iter()
                .map(|e| self.blocks_for(e.tokens))
                .sum::<usize>(),
            "SwapTier running block counter drifted from Σ over entries"
        );
        self.resident
    }

    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.iter().any(|e| e.id == id)
    }

    /// Tokens of a stashed snapshot, without removing it — the restore
    /// path's feasibility probe (page math before `take`).
    pub fn stashed_tokens(&self, id: u64) -> Option<usize> {
        self.entries.iter().find(|e| e.id == id).map(|e| e.tokens)
    }

    /// Whether a `tokens`-long snapshot fits the remaining budget.
    pub fn can_stash(&self, tokens: usize) -> bool {
        self.budget_blocks == 0
            || self.resident_blocks() + self.blocks_for(tokens)
                <= self.budget_blocks
    }

    /// Stash a suspended sequence's KV snapshot.  Returns `false` (and
    /// drops nothing — the caller still owns the sequence) when the
    /// budget would be exceeded or the id is already stashed.
    pub fn stash(
        &mut self,
        id: u64,
        tokens: usize,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> bool {
        if tokens == 0 || !self.can_stash(tokens) || self.contains(id) {
            return false;
        }
        self.entries.push(SwapEntry {
            id,
            tokens,
            k: HostKv::from_f32(k, self.row_d, self.quant),
            v: HostKv::from_f32(v, self.row_d, self.quant),
        });
        self.stashes += 1;
        self.resident += self.blocks_for(tokens);
        self.peak_blocks = self.peak_blocks.max(self.resident_blocks());
        true
    }

    /// Remove and return a stashed snapshot: `(tokens, k, v)`
    /// (dequantized back to f32 under `Int8` — bitwise the stashed
    /// floats, since the engine stashes canonical values).
    pub fn take(&mut self, id: u64) -> Option<(usize, Vec<f32>, Vec<f32>)> {
        let i = self.entries.iter().position(|e| e.id == id)?;
        let e = self.entries.swap_remove(i);
        self.restores += 1;
        self.resident -= self.blocks_for(e.tokens);
        Some((e.tokens, e.k.into_f32(), e.v.into_f32()))
    }

    /// Drop a stashed snapshot without restoring it (the sequence was
    /// shed or retired while suspended).  Returns whether an entry
    /// existed.
    pub fn discard(&mut self, id: u64) -> bool {
        match self.entries.iter().position(|e| e.id == id) {
            Some(i) => {
                let e = self.entries.swap_remove(i);
                self.resident -= self.blocks_for(e.tokens);
                true
            }
            None => false,
        }
    }
}

// ---------------------------------------------------------------------
// shared-prefix cache (DESIGN.md §Serving)

/// FNV-1a chain hash of one token block given the previous block's chain
/// hash (`0` for the first block).  Chaining makes block *i*'s hash a
/// digest of the whole prefix `[0, (i+1)·block)`, so two prompts share a
/// cached prefix iff their leading chain hashes agree — one u64 compare
/// per block instead of a token-by-token scan (token equality is still
/// verified on a hash match before any KV is reused; a collision can
/// cost a wasted compare, never a wrong seed).
pub fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    fn mix(mut h: u64, b: u8) -> u64 {
        h ^= b as u64;
        h.wrapping_mul(PRIME)
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in prev.to_le_bytes() {
        h = mix(h, b);
    }
    for &t in tokens {
        for b in t.to_le_bytes() {
            h = mix(h, b);
        }
    }
    h
}

/// Chain hashes of every complete `block`-token block of `tokens`
/// (the partial tail block is never hashed — prefix reuse is
/// block-granular by construction).
pub fn prefix_hashes(tokens: &[i32], block: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / block.max(1));
    let mut prev = 0u64;
    for chunk in tokens.chunks_exact(block) {
        prev = chain_hash(prev, chunk);
        out.push(prev);
    }
    out
}

/// One cached prompt prefix: its chain hashes, the exact tokens (hash
/// collisions are verified away), a host snapshot of the prefix K/V, and
/// the retained device-pool blocks covering it (empty when the donor had
/// no paged mirror).  `k`/`v` are `[n_layers, tokens, H, d]` row-major —
/// position-major within a layer so seeding a sequence is one contiguous
/// `H·d` row per (layer, pos) `SeqKvCache::append`.
struct PrefixEntry {
    hashes: Vec<u64>,
    tokens: Vec<i32>,
    k: HostKv,
    v: HostKv,
    /// Physical device-pool block ids pinned via `BlockAllocator::retain`
    /// at insert; aligned 1:1 with `hashes` up to its (possibly shorter)
    /// length.  Released — never copied — on eviction.
    dev_blocks: Vec<usize>,
    /// LRU clock value of the last hit/insert.
    last_use: u64,
}

/// LRU-bounded registry of cached prompt prefixes (the shared-prefix
/// tentpole, DESIGN.md §Serving; mistral.rs `PrefixCacheManager` is the
/// exemplar).  Prefixes are keyed by block-granular chain hashes; the
/// budget is counted in *blocks* (`max_blocks`), so the registry's host
/// footprint and its device-pool pin count are both bounded.  Eviction
/// releases the evicted entry's device-block refcounts through the
/// engine's `BlockAllocator` — it never copies KV.
pub struct PrefixCache {
    /// Hash-block granularity in tokens.  Equals the paged device pool's
    /// block size when the artifact set carries the paged stages (so one
    /// hash block pins exactly one device block), else the host
    /// `PagePool::page_len`.
    block: usize,
    /// Registry budget in blocks (Σ entry blocks ≤ this).
    max_blocks: usize,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
    /// Host-snapshot residency precision (`EngineConfig::kv_quant`);
    /// `Int8` quantizes per `head_dim`-length row on `insert` and
    /// dequantizes in `entry_row_into` — lossless, because the engine
    /// inserts canonical (already quantize→dequantize'd) floats.
    quant: KvQuant,
    tick: u64,
    entries: Vec<PrefixEntry>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// A successful [`PrefixCache::lookup`]: entry index + matched tokens
/// (always a positive multiple of the cache's block size, and strictly
/// shorter than the looked-up prompt so prefill always has a tail to
/// execute real logits from).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefixHit {
    pub entry: usize,
    pub tokens: usize,
}

impl PrefixCache {
    pub fn new(
        block: usize,
        max_blocks: usize,
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
    ) -> Self {
        Self::with_quant(block, max_blocks, n_layers, n_heads, head_dim, KvQuant::Off)
    }

    pub fn with_quant(
        block: usize,
        max_blocks: usize,
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
        quant: KvQuant,
    ) -> Self {
        assert!(block > 0, "prefix cache needs a positive block size");
        PrefixCache {
            block,
            max_blocks,
            n_layers,
            n_heads,
            head_dim,
            quant,
            tick: 0,
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Hash-block granularity in tokens.
    pub fn block(&self) -> usize {
        self.block
    }

    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Σ blocks across entries — the LRU budget's occupancy.
    pub fn blocks_cached(&self) -> usize {
        self.entries.iter().map(|e| e.hashes.len()).sum()
    }

    /// Shared match scan: longest token-verified cached prefix of
    /// `prompt` as `(blocks, entry index)`, with no counter or LRU-clock
    /// side effects.
    fn best_match(&self, prompt: &[i32]) -> Option<(usize, usize)> {
        let limit_blocks = prompt.len().saturating_sub(1) / self.block;
        let want = prefix_hashes(
            &prompt[..(limit_blocks * self.block).min(prompt.len())],
            self.block,
        );
        let mut best: Option<(usize, usize)> = None; // (blocks, idx)
        for (i, e) in self.entries.iter().enumerate() {
            let mut m = 0usize;
            while m < want.len()
                && m < e.hashes.len()
                && e.hashes[m] == want[m]
            {
                m += 1;
            }
            // hash-collision guard: reuse only token-verified prefixes
            while m > 0
                && e.tokens[..m * self.block] != prompt[..m * self.block]
            {
                m -= 1;
            }
            let better = match best {
                None => m > 0,
                Some((bm, bi)) => {
                    m > bm
                        || (m == bm
                            && self.entries[bi].last_use < e.last_use)
                }
            };
            if better && m > 0 {
                best = Some((m, i));
            }
        }
        best
    }

    /// Longest cached prefix of `prompt`, capped one token short of the
    /// whole prompt (the unshared tail must be ≥ 1 so prefill executes
    /// real final-chunk logits).  On a hit the entry's LRU clock is
    /// bumped; ties between equally-long matches go to the most recently
    /// used entry.
    pub fn lookup(&mut self, prompt: &[i32]) -> Option<PrefixHit> {
        match self.best_match(prompt) {
            Some((m, i)) => {
                self.hits += 1;
                self.tick += 1;
                self.entries[i].last_use = self.tick;
                Some(PrefixHit { entry: i, tokens: m * self.block })
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Side-effect-free probe: the tokens a [`lookup`](Self::lookup) at
    /// this instant would match, without perturbing hit/miss counters or
    /// LRU order.  Admission control uses this to estimate a warm
    /// request's unshared prefill tail (`Scheduler::submit`) — an
    /// estimate must not count as cache traffic or keep entries warm.
    pub fn peek(&self, prompt: &[i32]) -> usize {
        self.best_match(prompt).map_or(0, |(m, _)| m * self.block)
    }

    /// One contiguous `[H·d]` K row and V row for (layer, pos) of an
    /// entry — exactly the unit `SeqKvCache::append` consumes.  Borrowed
    /// f32 rows only exist with `kv_quant = off`; quant-proof callers
    /// use [`entry_row_into`](Self::entry_row_into).
    pub fn entry_row(
        &self,
        entry: usize,
        layer: usize,
        pos: usize,
    ) -> (&[f32], &[f32]) {
        let e = &self.entries[entry];
        let w = self.n_heads * self.head_dim;
        let off = (layer * e.tokens.len() + pos) * w;
        match (&e.k, &e.v) {
            (HostKv::F32(k), HostKv::F32(v)) => {
                (&k[off..off + w], &v[off..off + w])
            }
            _ => panic!(
                "entry_row: no borrowed f32 rows under int8 residency; \
                 use entry_row_into"
            ),
        }
    }

    /// Copy (dequantizing under `Int8`) one `[H·d]` K row and V row for
    /// (layer, pos) into `out_k`/`out_v` — the quant-proof twin of
    /// [`entry_row`](Self::entry_row), feeding `SeqKvCache::append` when
    /// a sequence seeds from this cache.
    pub fn entry_row_into(
        &self,
        entry: usize,
        layer: usize,
        pos: usize,
        out_k: &mut [f32],
        out_v: &mut [f32],
    ) {
        let e = &self.entries[entry];
        let (h, d) = (self.n_heads, self.head_dim);
        let w = h * d;
        let row0 = (layer * e.tokens.len() + pos) * h; // in d-rows
        match &e.k {
            HostKv::F32(k) => {
                out_k[..w].copy_from_slice(&k[row0 * d..row0 * d + w]);
            }
            HostKv::Int8(q) => q.dequantize_range(row0, h, &mut out_k[..w]),
        }
        match &e.v {
            HostKv::F32(v) => {
                out_v[..w].copy_from_slice(&v[row0 * d..row0 * d + w]);
            }
            HostKv::Int8(q) => q.dequantize_range(row0, h, &mut out_v[..w]),
        }
    }

    /// The entry's pinned device-pool blocks (may cover fewer blocks than
    /// the host snapshot when the donor's paged mirror was shorter or
    /// absent).
    pub fn entry_dev_blocks(&self, entry: usize) -> &[usize] {
        &self.entries[entry].dev_blocks
    }

    /// Register a finished sequence's context as a cached prefix.
    /// `tokens` must be a positive multiple of `block`; `k`/`v` are the
    /// `[n_layers, tokens, H, d]` host snapshot and `dev_blocks` carries
    /// refcounts this call now *owns* (retained by the caller; released
    /// here on rejection or later on eviction, via `alloc`).
    ///
    /// Dedup: an existing entry already covering `tokens` just has its
    /// LRU clock bumped (the new snapshot is dropped); an existing entry
    /// that is a strict prefix of `tokens` is replaced.  LRU entries are
    /// evicted until the budget fits; an insert larger than the whole
    /// budget is rejected.  Eviction/rejection releases device-block
    /// refcounts — it never copies.
    pub fn insert(
        &mut self,
        tokens: &[i32],
        k: Vec<f32>,
        v: Vec<f32>,
        dev_blocks: Vec<usize>,
        mut alloc: Option<&mut BlockAllocator>,
    ) -> bool {
        let mut drop_blocks = |blocks: &[usize], alloc: &mut Option<&mut BlockAllocator>| {
            if let Some(a) = alloc.as_deref_mut() {
                for &b in blocks {
                    a.release(b);
                }
            }
        };
        if tokens.is_empty()
            || tokens.len() % self.block != 0
            || tokens.len() / self.block > self.max_blocks
        {
            drop_blocks(&dev_blocks, &mut alloc);
            return false;
        }
        debug_assert_eq!(
            k.len(),
            self.n_layers * tokens.len() * self.n_heads * self.head_dim
        );
        let hashes = prefix_hashes(tokens, self.block);
        // covered by an existing entry: bump it, drop the new snapshot
        if let Some(e) = self.entries.iter_mut().find(|e| {
            e.hashes.len() >= hashes.len()
                && e.hashes[..hashes.len()] == hashes[..]
                && e.tokens[..tokens.len()] == tokens[..]
        }) {
            self.tick += 1;
            e.last_use = self.tick;
            drop_blocks(&dev_blocks, &mut alloc);
            return false;
        }
        // strict prefixes of the new entry are superseded by it
        let mut i = 0;
        while i < self.entries.len() {
            let e = &self.entries[i];
            if e.hashes.len() < hashes.len()
                && hashes[..e.hashes.len()] == e.hashes[..]
                && tokens[..e.tokens.len()] == e.tokens[..]
            {
                let old = self.entries.swap_remove(i);
                drop_blocks(&old.dev_blocks, &mut alloc);
            } else {
                i += 1;
            }
        }
        // LRU eviction until the budget fits (never copies — refcounts
        // just drop, and the pool frees a block at its last holder)
        while self.blocks_cached() + hashes.len() > self.max_blocks {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(i, _)| i)
                .expect("budget check guarantees an entry to evict");
            let old = self.entries.swap_remove(lru);
            drop_blocks(&old.dev_blocks, &mut alloc);
            self.evictions += 1;
        }
        self.tick += 1;
        let d = self.head_dim;
        self.entries.push(PrefixEntry {
            hashes,
            tokens: tokens.to_vec(),
            k: HostKv::from_f32(k, d, self.quant),
            v: HostKv::from_f32(v, d, self.quant),
            dev_blocks,
            last_use: self.tick,
        });
        true
    }

    /// Drop every entry, releasing all pinned device blocks.  The
    /// engine's leak checks call this before asserting the pool drains.
    pub fn clear(&mut self, mut alloc: Option<&mut BlockAllocator>) {
        for e in self.entries.drain(..) {
            if let Some(a) = alloc.as_deref_mut() {
                for &b in &e.dev_blocks {
                    a.release(b);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, Prop};
    use crate::util::rng::Rng;

    fn mk(n_layers: usize) -> (PagePool, SeqKvCache) {
        (PagePool::new(2, 4, 8), SeqKvCache::new(n_layers))
    }

    fn row(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Concurrency model (loom lane): page accounting under every
    /// interleaving of two sequences' alloc/alloc/release-all scripts
    /// against a capped pool.  A page id must never be live in two
    /// holders, `in_use + free == allocated` at every step, the cap is
    /// never exceeded, and the pool drains when both sequences finish —
    /// the invariants `BatchPolicy::admit` relies on when it gates on
    /// `available_pages`.
    #[test]
    fn loom_page_pool_accounting_all_interleavings() {
        use crate::analysis::sched::{explore, Op};
        use crate::sched_ops;

        #[derive(Clone)]
        struct St {
            pool: PagePool,
            held: [Vec<usize>; 2],
        }
        let grab = |s: &mut St, i: usize| {
            let id = s.pool.alloc().expect("cap 4 fits 2×2 pages");
            s.held[i].push(id);
        };
        let script = |i: usize| -> Vec<Op<St>> {
            sched_ops![
                move |s: &mut St| grab(s, i),
                move |s: &mut St| grab(s, i),
                move |s: &mut St| {
                    for id in s.held[i].drain(..) {
                        s.pool.release(id);
                    }
                },
            ]
        };
        let n = explore(
            &St {
                pool: PagePool::with_limit(2, 4, 8, 4),
                held: [Vec::new(), Vec::new()],
            },
            &[script(0), script(1)],
            &|s| {
                let mut live = std::collections::HashSet::new();
                for id in s.held.iter().flatten() {
                    if !live.insert(*id) {
                        return Err(format!("page {id} held twice"));
                    }
                }
                if s.pool.in_use_pages() != live.len() {
                    return Err(format!(
                        "in_use {} != held {}",
                        s.pool.in_use_pages(),
                        live.len()
                    ));
                }
                if s.pool.free_pages() + live.len() != s.pool.allocated_pages()
                {
                    return Err("free + in_use != allocated".into());
                }
                if s.pool.allocated_pages() > s.pool.max_pages() {
                    return Err("cap exceeded".into());
                }
                Ok(())
            },
            &|s| {
                if s.pool.in_use_pages() == 0 {
                    Ok(())
                } else {
                    Err(format!("{} pages leaked", s.pool.in_use_pages()))
                }
            },
        )
        .unwrap_or_else(|v| panic!("{v}"));
        assert_eq!(n, 20, "C(6,3) interleavings of two 3-op scripts");
    }

    /// Issue satellite: the paged-pool allocator under a random schedule
    /// of alloc / retain / release across several holders.  A physical
    /// block must never be handed out twice while live, refcounts must
    /// equal the model's holder counts, `free + in_use == capacity` at
    /// every step, and the pool drains when every holder releases.
    #[test]
    fn prop_blocks_never_double_alloc_or_leak() {
        Prop::new(40, 0xB10C).forall(
            |rng| {
                let cap = gen::usize_in(rng, 1, 10);
                let ops: Vec<(usize, u8)> = (0..60)
                    .map(|_| (rng.below(4), rng.below(3) as u8))
                    .collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut ba = BlockAllocator::new(*cap);
                // model: per-holder multiset of held block ids
                let mut held: Vec<Vec<usize>> = vec![Vec::new(); 4];
                for &(holder, op) in ops {
                    match op {
                        0 => {
                            if let Some(id) = ba.alloc() {
                                if held.iter().flatten().any(|&h| h == id) {
                                    return Err(format!(
                                        "block {id} double-allocated"
                                    ));
                                }
                                held[holder].push(id);
                            } else if ba.free_blocks() > 0 {
                                return Err("alloc failed with free blocks"
                                    .into());
                            }
                        }
                        1 => {
                            // share a live block (cross-holder retain)
                            let live = held.iter().flatten().next().copied();
                            if let Some(id) = live {
                                ba.retain(id);
                                held[holder].push(id);
                            }
                        }
                        _ => {
                            if let Some(id) = held[holder].pop() {
                                ba.release(id);
                            }
                        }
                    }
                    let mut counts = vec![0u32; *cap];
                    for &id in held.iter().flatten() {
                        counts[id] += 1;
                    }
                    for (id, &c) in counts.iter().enumerate() {
                        if ba.ref_count(id) != c {
                            return Err(format!(
                                "block {id}: refcount {} != model {c}",
                                ba.ref_count(id)
                            ));
                        }
                    }
                    let live = counts.iter().filter(|&&c| c > 0).count();
                    if ba.in_use() != live {
                        return Err(format!(
                            "in_use {} != live {live}",
                            ba.in_use()
                        ));
                    }
                    if ba.free_blocks() + ba.in_use() != ba.capacity() {
                        return Err("free + in_use != capacity".into());
                    }
                }
                for ids in &mut held {
                    for id in ids.drain(..) {
                        ba.release(id);
                    }
                }
                if ba.in_use() != 0 {
                    return Err(format!("{} blocks leaked", ba.in_use()));
                }
                Ok(())
            },
        );
    }

    /// Concurrency model (loom lane): block accounting under every
    /// interleaving of two sequences' grow/grow/release-all scripts
    /// against a shared allocator — the schedule the engine's paged
    /// append pass runs when two sequences cross a block boundary in the
    /// same scheduler iteration.  No block may be live in two tables
    /// (absent an explicit retain), `free + in_use == capacity` at every
    /// step, and the pool drains when both sequences finish.
    #[test]
    fn loom_block_allocator_accounting_all_interleavings() {
        use crate::analysis::sched::{explore, Op};
        use crate::sched_ops;

        #[derive(Clone)]
        struct St {
            ba: BlockAllocator,
            tables: [Vec<usize>; 2],
        }
        let grow = |s: &mut St, i: usize| {
            let id = s.ba.alloc().expect("capacity 4 fits 2×2 blocks");
            s.tables[i].push(id);
        };
        let script = |i: usize| -> Vec<Op<St>> {
            sched_ops![
                move |s: &mut St| grow(s, i),
                move |s: &mut St| grow(s, i),
                move |s: &mut St| {
                    for id in s.tables[i].drain(..) {
                        s.ba.release(id);
                    }
                },
            ]
        };
        let n = explore(
            &St {
                ba: BlockAllocator::new(4),
                tables: [Vec::new(), Vec::new()],
            },
            &[script(0), script(1)],
            &|s| {
                let mut live = std::collections::HashSet::new();
                for id in s.tables.iter().flatten() {
                    if !live.insert(*id) {
                        return Err(format!("block {id} in two tables"));
                    }
                }
                if s.ba.in_use() != live.len() {
                    return Err(format!(
                        "in_use {} != held {}",
                        s.ba.in_use(),
                        live.len()
                    ));
                }
                if s.ba.free_blocks() + s.ba.in_use() != s.ba.capacity() {
                    return Err("free + in_use != capacity".into());
                }
                Ok(())
            },
            &|s| {
                if s.ba.in_use() == 0 {
                    Ok(())
                } else {
                    Err(format!("{} blocks leaked", s.ba.in_use()))
                }
            },
        )
        .unwrap_or_else(|v| panic!("{v}"));
        assert_eq!(n, 20, "C(6,3) interleavings of two 3-op scripts");
    }

    /// Refcounted sharing: a retained block survives its first holder's
    /// release and frees only when the last holder drops it.
    #[test]
    fn block_sharing_frees_on_last_release() {
        let mut ba = BlockAllocator::new(2);
        let a = ba.alloc().unwrap();
        ba.retain(a); // second holder (e.g. a prefix-cache hit)
        assert_eq!(ba.ref_count(a), 2);
        ba.release(a);
        assert_eq!(ba.in_use(), 1, "block must survive the first release");
        ba.release(a);
        assert_eq!(ba.in_use(), 0);
        // freed id is reusable and capacity accounting holds
        let b = ba.alloc().unwrap();
        let c = ba.alloc().unwrap();
        assert_ne!(b, c);
        assert!(ba.alloc().is_none(), "pool of 2 is exhausted");
        assert_eq!(ba.free_blocks() + ba.in_use(), ba.capacity());
    }

    /// Paged mirror capacity tracks the block table, not a compiled
    /// bucket.
    #[test]
    fn paged_mirror_capacity_is_table_span() {
        let mut m =
            DevKvMirror::Paged { blocks: vec![3, 0, 7], block: 64, len: 130 };
        assert_eq!(m.lb(), 192);
        assert_eq!(m.len(), 130);
        m.set_len(131);
        assert_eq!(m.len(), 131);
        if let DevKvMirror::Paged { blocks, .. } = &mut m {
            blocks.push(5);
        }
        assert_eq!(m.lb(), 256, "capacity grows with the table");
    }

    #[test]
    fn append_then_read_roundtrip() {
        let (mut pool, mut c) = mk(2);
        let mut rng = Rng::new(1);
        let mut rows = Vec::new();
        for _t in 0..20 {
            let (k0, v0) = (row(&mut rng, 8), row(&mut rng, 8));
            let (k1, v1) = (row(&mut rng, 8), row(&mut rng, 8));
            c.append(&mut pool, 0, &k0, &v0).unwrap();
            c.append(&mut pool, 1, &k1, &v1).unwrap();
            c.commit_token();
            rows.push((k0, v0, k1, v1));
        }
        assert_eq!(c.len(), 20);
        for (t, (k0, v0, k1, v1)) in rows.iter().enumerate() {
            for h in 0..2 {
                assert_eq!(c.key(&pool, 0, h, t), &k0[h * 4..(h + 1) * 4]);
                assert_eq!(c.value(&pool, 0, h, t), &v0[h * 4..(h + 1) * 4]);
                assert_eq!(c.key(&pool, 1, h, t), &k1[h * 4..(h + 1) * 4]);
                assert_eq!(c.value(&pool, 1, h, t), &v1[h * 4..(h + 1) * 4]);
            }
        }
    }

    #[test]
    fn gather_matches_key_accessor() {
        let (mut pool, mut c) = mk(1);
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            c.append(&mut pool, 0, &row(&mut rng, 8), &row(&mut rng, 8))
                .unwrap();
            c.commit_token();
        }
        let idx = [0usize, 7, 8, 15, 16, 29];
        let mut gk = vec![0f32; idx.len() * 4];
        let mut gv = vec![0f32; idx.len() * 4];
        c.gather(&pool, 0, 1, &idx, &mut gk, &mut gv);
        for (i, &p) in idx.iter().enumerate() {
            assert_eq!(&gk[i * 4..(i + 1) * 4], c.key(&pool, 0, 1, p));
            assert_eq!(&gv[i * 4..(i + 1) * 4], c.value(&pool, 0, 1, p));
        }
    }

    #[test]
    fn export_dense_layout() {
        let (mut pool, mut c) = mk(1);
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            c.append(&mut pool, 0, &row(&mut rng, 8), &row(&mut rng, 8))
                .unwrap();
            c.commit_token();
        }
        let l_max = 16;
        let mut k = vec![0f32; 2 * l_max * 4];
        let mut v = vec![0f32; 2 * l_max * 4];
        c.export_dense(&pool, 0, l_max, &mut k, &mut v);
        for h in 0..2 {
            for p in 0..10 {
                let dst = (h * l_max + p) * 4;
                assert_eq!(&k[dst..dst + 4], c.key(&pool, 0, h, p));
            }
            // padding stays zero
            let dst = (h * l_max + 12) * 4;
            assert_eq!(&k[dst..dst + 4], &[0.0; 4]);
        }
    }

    /// Issue satellite (GQA latent bug): `export_dense_kv` must stage
    /// exactly `Hkv` unexpanded rows from a GQA-expanded pool — the
    /// group leader per KV group — into a tile sized by `Hkv`, and must
    /// degenerate to `export_dense` when `Hkv == H`.
    #[test]
    fn export_dense_kv_stages_group_leaders() {
        // pool with H = 4 expanded heads; appends duplicate rows in
        // groups of rep = 2, exactly like the engine's GQA expansion
        let mut pool = PagePool::new(4, 4, 8);
        let mut c = SeqKvCache::new(1);
        let mut rng = Rng::new(12);
        let (h, hkv, d, rep) = (4usize, 2usize, 4usize, 2usize);
        for _ in 0..10 {
            let mut k = vec![0f32; h * d];
            let mut v = vec![0f32; h * d];
            for g in 0..hkv {
                let kr = row(&mut rng, d);
                let vr = row(&mut rng, d);
                for r in 0..rep {
                    let hh = g * rep + r;
                    k[hh * d..(hh + 1) * d].copy_from_slice(&kr);
                    v[hh * d..(hh + 1) * d].copy_from_slice(&vr);
                }
            }
            c.append(&mut pool, 0, &k, &v).unwrap();
            c.commit_token();
        }
        let l_max = 16;
        let mut k = vec![0f32; hkv * l_max * d];
        let mut v = vec![0f32; hkv * l_max * d];
        c.export_dense_kv(&pool, 0, l_max, hkv, &mut k, &mut v);
        for g in 0..hkv {
            for p in 0..10 {
                let dst = (g * l_max + p) * d;
                // kv-head g == expanded group leader g·rep
                assert_eq!(&k[dst..dst + d], c.key(&pool, 0, g * rep, p));
                assert_eq!(&v[dst..dst + d], c.value(&pool, 0, g * rep, p));
            }
            // padding stays zero
            let dst = (g * l_max + 12) * d;
            assert_eq!(&k[dst..dst + d], &[0.0; 4]);
        }
        // Hkv == H degenerates to export_dense exactly
        let mut ka = vec![0f32; h * l_max * d];
        let mut va = vec![0f32; h * l_max * d];
        let mut kb = vec![0f32; h * l_max * d];
        let mut vb = vec![0f32; h * l_max * d];
        c.export_dense(&pool, 0, l_max, &mut ka, &mut va);
        c.export_dense_kv(&pool, 0, l_max, h, &mut kb, &mut vb);
        assert_eq!(ka, kb);
        assert_eq!(va, vb);
    }

    #[test]
    fn release_returns_pages_and_reuse() {
        let (mut pool, mut c) = mk(2);
        let mut rng = Rng::new(4);
        for _ in 0..17 {
            for l in 0..2 {
                c.append(&mut pool, l, &row(&mut rng, 8), &row(&mut rng, 8))
                    .unwrap();
            }
            c.commit_token();
        }
        // 17 tokens, page_len 8 → 3 pages per layer → 6 pages.
        assert_eq!(pool.in_use_pages(), 6);
        c.release(&mut pool);
        assert_eq!(pool.in_use_pages(), 0);
        assert_eq!(pool.free_pages(), 6);
        // A new sequence reuses freed pages without growing the pool.
        let mut c2 = SeqKvCache::new(2);
        for _ in 0..8 {
            for l in 0..2 {
                c2.append(&mut pool, l, &row(&mut rng, 8), &row(&mut rng, 8))
                    .unwrap();
            }
            c2.commit_token();
        }
        assert_eq!(pool.allocated_pages(), 6);
    }

    #[test]
    fn append_size_mismatch_errors() {
        let (mut pool, mut c) = mk(1);
        assert!(c.append(&mut pool, 0, &[0.0; 3], &[0.0; 8]).is_err());
    }

    #[test]
    fn prop_pool_accounting_never_leaks() {
        // Invariant: pages_held(seqs) == in_use_pages(pool) across a random
        // schedule of appends and releases.
        Prop::new(30, 0xCACE).forall(
            |rng| {
                let n_seqs = gen::usize_in(rng, 1, 5);
                let ops: Vec<(usize, bool)> = (0..40)
                    .map(|_| (rng.below(n_seqs), rng.f32() < 0.15))
                    .collect();
                (n_seqs, ops)
            },
            |(n_seqs, ops)| {
                let mut pool = PagePool::new(2, 4, 4);
                let mut seqs: Vec<SeqKvCache> =
                    (0..*n_seqs).map(|_| SeqKvCache::new(2)).collect();
                let mut rng = Rng::new(9);
                for &(s, is_release) in ops {
                    if is_release {
                        seqs[s].release(&mut pool);
                    } else {
                        for l in 0..2 {
                            let k = row(&mut rng, 8);
                            let v = row(&mut rng, 8);
                            seqs[s].append(&mut pool, l, &k, &v).unwrap();
                        }
                        seqs[s].commit_token();
                    }
                    let held: usize =
                        seqs.iter().map(SeqKvCache::pages_held).sum();
                    if held != pool.in_use_pages() {
                        return Err(format!(
                            "held {held} != in_use {}",
                            pool.in_use_pages()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn load_prefill_range_in_chunks_matches_whole() {
        // Loading [0,3) then [3,5) must equal a single [0,5) load.
        let (h, d, l_max, len) = (2usize, 4usize, 8usize, 5usize);
        let mut rng = Rng::new(6);
        let k: Vec<f32> =
            (0..2 * h * l_max * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> =
            (0..2 * h * l_max * d).map(|_| rng.normal()).collect();

        let (mut pool_a, mut a) = mk(2);
        a.load_prefill(&mut pool_a, &k, &v, l_max, len).unwrap();

        let (mut pool_b, mut b) = mk(2);
        b.load_prefill_range(&mut pool_b, &k, &v, l_max, 0, 3).unwrap();
        assert_eq!(b.len(), 3);
        b.load_prefill_range(&mut pool_b, &k, &v, l_max, 3, len).unwrap();
        assert_eq!(b.len(), len);

        for layer in 0..2 {
            for head in 0..h {
                for pos in 0..len {
                    assert_eq!(
                        a.key(&pool_a, layer, head, pos),
                        b.key(&pool_b, layer, head, pos)
                    );
                    assert_eq!(
                        a.value(&pool_a, layer, head, pos),
                        b.value(&pool_b, layer, head, pos)
                    );
                }
            }
        }
    }

    #[test]
    fn load_prefill_range_rejects_gaps() {
        let (mut pool, mut c) = mk(1);
        let (h, d, l_max) = (2usize, 4usize, 8usize);
        let k = vec![0f32; h * l_max * d];
        let v = vec![0f32; h * l_max * d];
        // start beyond the cached length: would leave a hole
        assert!(c.load_prefill_range(&mut pool, &k, &v, l_max, 2, 4).is_err());
        // end past the artifact width
        assert!(c
            .load_prefill_range(&mut pool, &k, &v, l_max, 0, l_max + 1)
            .is_err());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn load_chunk_matches_append_path() {
        // Chunk-relative bulk load == the per-(pos, layer) append path,
        // across page boundaries (page_len 8, chunks of 5).
        let (h, d, cw) = (2usize, 4usize, 5usize);
        let mut rng = Rng::new(7);
        let (mut pool_a, mut a) = mk(2);
        let (mut pool_b, mut b) = mk(2);
        let mut pos_total = 0usize;
        for _chunk in 0..4 {
            let k: Vec<f32> =
                (0..2 * h * cw * d).map(|_| rng.normal()).collect();
            let v: Vec<f32> =
                (0..2 * h * cw * d).map(|_| rng.normal()).collect();
            b.load_chunk(&mut pool_b, &k, &v, cw, cw).unwrap();
            // reference: row-at-a-time appends
            let mut krow = vec![0f32; h * d];
            let mut vrow = vec![0f32; h * d];
            for p in 0..cw {
                for layer in 0..2 {
                    for head in 0..h {
                        let src = ((layer * h + head) * cw + p) * d;
                        krow[head * d..(head + 1) * d]
                            .copy_from_slice(&k[src..src + d]);
                        vrow[head * d..(head + 1) * d]
                            .copy_from_slice(&v[src..src + d]);
                    }
                    a.append(&mut pool_a, layer, &krow, &vrow).unwrap();
                }
                a.commit_token();
            }
            pos_total += cw;
        }
        assert_eq!(a.len(), pos_total);
        assert_eq!(b.len(), pos_total);
        for layer in 0..2 {
            for head in 0..h {
                for p in 0..pos_total {
                    assert_eq!(
                        a.key(&pool_a, layer, head, p),
                        b.key(&pool_b, layer, head, p)
                    );
                    assert_eq!(
                        a.value(&pool_a, layer, head, p),
                        b.value(&pool_b, layer, head, p)
                    );
                }
            }
        }
    }

    #[test]
    fn load_chunk_partial_count_and_size_checks() {
        let (mut pool, mut c) = mk(1);
        let (h, d, cw) = (2usize, 4usize, 8usize);
        let mut rng = Rng::new(8);
        let k: Vec<f32> = (0..h * cw * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> = (0..h * cw * d).map(|_| rng.normal()).collect();
        // partial (ragged last chunk): only 3 of 8 tile rows are valid
        c.load_chunk(&mut pool, &k, &v, cw, 3).unwrap();
        assert_eq!(c.len(), 3);
        for p in 0..3 {
            let src = p * d; // tile row p of (layer 0, head 0)
            assert_eq!(c.key(&pool, 0, 0, p), &k[src..src + d]);
        }
        // count beyond the tile width and bad tile sizes are rejected
        assert!(c.load_chunk(&mut pool, &k, &v, cw, cw + 1).is_err());
        assert!(c.load_chunk(&mut pool, &k[1..], &v, cw, 1).is_err());
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn pool_cap_makes_alloc_fallible() {
        // cap = 2 pages, page_len 4, 1 layer → 8 tokens fit, the 9th fails
        let mut pool = PagePool::with_limit(2, 4, 4, 2);
        let mut c = SeqKvCache::new(1);
        let mut rng = Rng::new(9);
        for _ in 0..8 {
            c.append(&mut pool, 0, &row(&mut rng, 8), &row(&mut rng, 8))
                .unwrap();
            c.commit_token();
        }
        assert_eq!(pool.available_pages(), 0);
        let err = c
            .append(&mut pool, 0, &row(&mut rng, 8), &row(&mut rng, 8))
            .unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        assert_eq!(c.len(), 8, "failed append must not advance state");
        // releasing returns headroom and allocation succeeds again
        c.release(&mut pool);
        assert_eq!(pool.available_pages(), 2);
        let mut c2 = SeqKvCache::new(1);
        c2.append(&mut pool, 0, &row(&mut rng, 8), &row(&mut rng, 8))
            .unwrap();
        // uncapped pools report unbounded availability
        assert_eq!(PagePool::new(2, 4, 4).available_pages(), usize::MAX);
    }

    #[test]
    fn load_rows_cap_failure_leaves_length_unchanged() {
        // 2 layers need 2 pages for any token; cap 1 → the bulk load must
        // fail before any row copy and leave len() at 0 (the allocated
        // page stays held by the sequence and is released with it).
        let mut pool = PagePool::with_limit(2, 4, 4, 1);
        let mut c = SeqKvCache::new(2);
        let (h, d, l_max) = (2usize, 4usize, 4usize);
        let k = vec![1f32; 2 * h * l_max * d];
        let v = vec![2f32; 2 * h * l_max * d];
        assert!(c.load_prefill(&mut pool, &k, &v, l_max, 2).is_err());
        assert_eq!(c.len(), 0);
        assert_eq!(c.pages_held(), pool.in_use_pages());
        c.release(&mut pool);
        assert_eq!(pool.in_use_pages(), 0);
    }

    #[test]
    fn prop_capped_pool_never_exceeds_limit() {
        // Random append/release schedules against a capped pool: the pool
        // never allocates past the cap, failures only happen at the cap,
        // and accounting (pages_held == in_use) survives failures.
        Prop::new(30, 0xCAB5).forall(
            |rng| {
                let cap = 1 + gen::usize_in(rng, 1, 8);
                let ops: Vec<(usize, bool)> = (0..60)
                    .map(|_| (rng.below(3), rng.f32() < 0.2))
                    .collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut pool = PagePool::with_limit(2, 4, 4, *cap);
                let mut seqs: Vec<SeqKvCache> =
                    (0..3).map(|_| SeqKvCache::new(2)).collect();
                let mut rng = Rng::new(11);
                for &(s, is_release) in ops {
                    if is_release {
                        seqs[s].release(&mut pool);
                    } else {
                        for l in 0..2 {
                            let k = row(&mut rng, 8);
                            let v = row(&mut rng, 8);
                            if seqs[s].append(&mut pool, l, &k, &v).is_err() {
                                if pool.available_pages() > 0 {
                                    return Err(format!(
                                        "alloc failed with {} available",
                                        pool.available_pages()
                                    ));
                                }
                                break;
                            }
                        }
                        // only commit fully-appended tokens
                        if seqs[s].tables.iter().all(|t| {
                            t.len() * pool.page_len > seqs[s].len
                        }) {
                            seqs[s].commit_token();
                        }
                    }
                    if pool.allocated_pages() > *cap {
                        return Err(format!(
                            "allocated {} > cap {cap}",
                            pool.allocated_pages()
                        ));
                    }
                    let held: usize =
                        seqs.iter().map(SeqKvCache::pages_held).sum();
                    if held != pool.in_use_pages() {
                        return Err(format!(
                            "held {held} != in_use {}",
                            pool.in_use_pages()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn load_prefill_all_matches_split_load() {
        // The packed [2, nl, H, l_max, d] bulk load (device-resident
        // prefill completion) must equal loading the K/V halves through
        // load_prefill, and must reject bad sizes / non-empty caches.
        let (h, d, l_max, len) = (2usize, 4usize, 8usize, 5usize);
        let mut rng = Rng::new(10);
        let half = 2 * h * l_max * d;
        let kv: Vec<f32> = (0..2 * half).map(|_| rng.normal()).collect();

        let (mut pool_a, mut a) = mk(2);
        a.load_prefill(&mut pool_a, &kv[..half], &kv[half..], l_max, len)
            .unwrap();
        let (mut pool_b, mut b) = mk(2);
        b.load_prefill_all(&mut pool_b, &kv, l_max, len).unwrap();
        assert_eq!(b.len(), len);
        for layer in 0..2 {
            for head in 0..h {
                for pos in 0..len {
                    assert_eq!(
                        a.key(&pool_a, layer, head, pos),
                        b.key(&pool_b, layer, head, pos)
                    );
                    assert_eq!(
                        a.value(&pool_a, layer, head, pos),
                        b.value(&pool_b, layer, head, pos)
                    );
                }
            }
        }
        // bad packed size and a non-empty cache are rejected
        assert!(b.load_prefill_all(&mut pool_b, &kv, l_max, len).is_err());
        let (mut pool_c, mut c) = mk(2);
        assert!(c.load_prefill_all(&mut pool_c, &kv[1..], l_max, len).is_err());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn load_prefill_roundtrip() {
        let (mut pool, mut c) = mk(2);
        let (h, d, l_max, len) = (2, 4, 8, 5);
        let mut rng = Rng::new(5);
        let k: Vec<f32> =
            (0..2 * h * l_max * d).map(|_| rng.normal()).collect();
        let v: Vec<f32> =
            (0..2 * h * l_max * d).map(|_| rng.normal()).collect();
        c.load_prefill(&mut pool, &k, &v, l_max, len).unwrap();
        assert_eq!(c.len(), len);
        for layer in 0..2 {
            for head in 0..h {
                for pos in 0..len {
                    let src = ((layer * h + head) * l_max + pos) * d;
                    assert_eq!(
                        c.key(&pool, layer, head, pos),
                        &k[src..src + d]
                    );
                }
            }
        }
    }

    // -----------------------------------------------------------------
    // prefix cache

    /// Host KV snapshot in the entry layout: `[nl, tokens, h, d]` with a
    /// value derived from its coordinates so reuse checks are exact.
    fn snap(nl: usize, tokens: usize, h: usize, d: usize, tag: f32) -> Vec<f32> {
        (0..nl * tokens * h * d)
            .map(|i| tag + i as f32)
            .collect()
    }

    /// Chain hashing is a prefix digest: hashes of a longer prompt start
    /// with the hashes of every shorter prompt sharing its prefix, and
    /// diverge at (and after) the first differing block.
    #[test]
    fn chain_hash_is_a_prefix_digest() {
        let block = 4;
        let long: Vec<i32> = (0..16).collect();
        let hl = prefix_hashes(&long, block);
        assert_eq!(hl.len(), 4);
        for cut in 1..=4 {
            let hs = prefix_hashes(&long[..cut * block], block);
            assert_eq!(hs[..], hl[..cut]);
        }
        // partial tail block is never hashed
        assert_eq!(prefix_hashes(&long[..block + 1], block).len(), 1);
        // a change in block 1 leaves block 0's hash alone but changes
        // every chained hash from block 1 on
        let mut other = long.clone();
        other[block] += 1;
        let ho = prefix_hashes(&other, block);
        assert_eq!(ho[0], hl[0]);
        assert!(ho[1..].iter().zip(&hl[1..]).all(|(a, b)| a != b));
    }

    /// `lookup` returns the longest token-verified match, strictly
    /// shorter than the prompt (the tail is executed, never seeded), and
    /// bumps the hit entry's LRU clock.
    #[test]
    fn prefix_lookup_longest_match_and_tail_guard() {
        let (block, nl, h, d) = (4, 1, 2, 3);
        let mut pc = PrefixCache::new(block, 16, nl, h, d);
        let toks: Vec<i32> = (100..116).collect();
        let mk = |n: usize, tag: f32| {
            (
                toks[..n].to_vec(),
                snap(nl, n, h, d, tag),
                snap(nl, n, h, d, -tag),
            )
        };
        let (t8, k8, v8) = mk(8, 1.0);
        assert!(pc.insert(&t8, k8, v8, Vec::new(), None));
        let (t12, k12, v12) = mk(12, 2.0);
        assert!(pc.insert(&t12, k12, v12, Vec::new(), None));
        // inserting t12 superseded t8 (a strict prefix of it)
        assert_eq!(pc.entries(), 1);

        // whole prompt cached → match caps at prompt.len()-1 rounded
        // down to a block boundary (here: 8 of 12 tokens)
        let hit = pc.lookup(&toks[..12]).expect("prefix cached");
        assert_eq!(hit.tokens, 8, "tail of ≥1 token must stay unshared");
        // longer prompt sharing all 12 tokens → full 12-token match
        let hit = pc.lookup(&toks).expect("prefix cached");
        assert_eq!(hit.tokens, 12);
        // entry rows round-trip the snapshot at the entry layout
        let (kr, vr) = pc.entry_row(hit.entry, 0, 5);
        assert_eq!(kr, &snap(nl, 12, h, d, 2.0)[5 * h * d..6 * h * d]);
        assert_eq!(vr, &snap(nl, 12, h, d, -2.0)[5 * h * d..6 * h * d]);
        // diverging block 0 → miss
        let mut cold = toks.clone();
        cold[0] += 1;
        assert!(pc.lookup(&cold).is_none());
        assert_eq!((pc.hits, pc.misses), (2, 1));
    }

    /// A chain-hash collision cannot seed wrong KV: token equality is
    /// re-verified, so a forged entry with matching hashes but different
    /// tokens is never returned.
    #[test]
    fn prefix_lookup_rejects_hash_collisions() {
        let (block, nl, h, d) = (2, 1, 1, 2);
        let mut pc = PrefixCache::new(block, 8, nl, h, d);
        let toks: Vec<i32> = vec![1, 2, 3, 4];
        let k = snap(nl, 4, h, d, 0.0);
        let v = snap(nl, 4, h, d, 0.5);
        assert!(pc.insert(&toks, k, v, Vec::new(), None));
        // forge a collision: same hashes, different tokens
        pc.entries[0].tokens = vec![9, 9, 9, 9];
        assert!(pc.lookup(&[1, 2, 3, 4, 5]).is_none());
    }

    /// LRU eviction under a block budget releases the evicted entry's
    /// device refcounts (never copies); an insert larger than the whole
    /// budget is rejected and its refcounts released immediately.
    #[test]
    fn prefix_insert_evicts_lru_and_releases_refcounts() {
        let (block, nl, h, d) = (2, 1, 1, 2);
        let mut ba = BlockAllocator::new(8);
        let mut pc = PrefixCache::new(block, 4, nl, h, d);
        // three 2-block entries against a 4-block budget
        let mut ins = |toks: &[i32], ba: &mut BlockAllocator| {
            let dev: Vec<usize> =
                (0..toks.len() / block).map(|_| ba.alloc().unwrap()).collect();
            pc.insert(
                toks,
                snap(nl, toks.len(), h, d, 0.0),
                snap(nl, toks.len(), h, d, 0.0),
                dev,
                Some(ba),
            )
        };
        assert!(ins(&[1, 2, 3, 4], &mut ba));
        assert!(ins(&[5, 6, 7, 8], &mut ba));
        assert_eq!((pc.blocks_cached(), ba.in_use()), (4, 4));
        // keep the first entry warm, then overflow: the *second* entry
        // is the LRU victim and its blocks free
        assert!(pc.lookup(&[1, 2, 3, 4, 0]).is_some());
        assert!(ins(&[9, 10, 11, 12], &mut ba));
        assert_eq!(pc.evictions, 1);
        assert_eq!((pc.blocks_cached(), ba.in_use()), (4, 4));
        assert!(pc.lookup(&[5, 6, 7, 8, 0]).is_none(), "LRU entry evicted");
        assert!(pc.lookup(&[1, 2, 3, 4, 0]).is_some(), "warm entry kept");
        // over-budget insert: rejected, refcounts released
        let before = ba.in_use();
        assert!(!ins(&(20..32).collect::<Vec<i32>>(), &mut ba));
        assert_eq!(ba.in_use(), before);
        // duplicate insert: bumped, new refcounts released
        assert!(!ins(&[1, 2, 3, 4], &mut ba));
        assert_eq!(ba.in_use(), before);
        // clear drains every pinned block
        pc.clear(Some(&mut ba));
        assert_eq!(ba.in_use(), 0);
        assert_eq!(pc.entries(), 0);
    }

    /// Issue satellite: `BlockAllocator::retain` under prefix-cache
    /// eviction.  Random schedule of insert (retaining live blocks into
    /// the cache), lookup+retain (a warm sequence pinning the hit
    /// entry's blocks into its own table), sequence release, and
    /// over-budget inserts forcing LRU eviction — refcounts must always
    /// equal cache-pins + sequence-pins per block, eviction must never
    /// free a block a sequence still holds, and the pool must drain
    /// after `clear` + all sequence releases.
    #[test]
    fn prop_prefix_retain_under_eviction() {
        let (block, nl, h, d) = (2, 1, 1, 2);
        Prop::new(40, 0x9EF1_B10C).forall(
            |rng| {
                let budget = gen::usize_in(rng, 2, 6);
                let ops: Vec<(u8, usize)> = (0..40)
                    .map(|_| (rng.below(3) as u8, rng.below(4)))
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let mut ba = BlockAllocator::new(16);
                let mut pc = PrefixCache::new(block, *budget, nl, h, d);
                // model: per-sequence pinned blocks
                let mut seqs: Vec<Vec<usize>> = vec![Vec::new(); 4];
                let mut next_tok = 0i32;
                for &(op, slot) in ops {
                    match op {
                        0 => {
                            // donor release → insert a fresh 1–3 block
                            // prefix with freshly-allocated dev blocks
                            let nb = 1 + (slot % 3);
                            let mut dev = Vec::new();
                            for _ in 0..nb {
                                match ba.alloc() {
                                    Some(id) => dev.push(id),
                                    None => break,
                                }
                            }
                            if dev.len() < nb {
                                for id in dev {
                                    ba.release(id);
                                }
                                continue;
                            }
                            let toks: Vec<i32> = (0..(nb * block) as i32)
                                .map(|i| next_tok + i)
                                .collect();
                            next_tok += 100;
                            pc.insert(
                                &toks,
                                snap(nl, toks.len(), h, d, 0.0),
                                snap(nl, toks.len(), h, d, 0.0),
                                dev,
                                Some(&mut ba),
                            );
                        }
                        1 => {
                            // warm admission: retain the hit entry's
                            // blocks into sequence `slot`'s table
                            let probe: Vec<i32> =
                                pc.entries.first().map_or_else(Vec::new, |e| {
                                    let mut t = e.tokens.clone();
                                    t.push(-1);
                                    t
                                });
                            if let Some(hit) = pc.lookup(&probe) {
                                for &b in pc.entry_dev_blocks(hit.entry) {
                                    ba.retain(b);
                                    seqs[slot].push(b);
                                }
                            }
                        }
                        _ => {
                            for id in seqs[slot].drain(..) {
                                ba.release(id);
                            }
                        }
                    }
                    // invariant: refcount == cache pins + sequence pins
                    let mut want = vec![0u32; ba.capacity()];
                    for e in &pc.entries {
                        for &b in &e.dev_blocks {
                            want[b] += 1;
                        }
                    }
                    for &b in seqs.iter().flatten() {
                        want[b] += 1;
                    }
                    for (id, &c) in want.iter().enumerate() {
                        if ba.ref_count(id) != c {
                            return Err(format!(
                                "block {id}: refcount {} != pins {c}",
                                ba.ref_count(id)
                            ));
                        }
                    }
                    if pc.blocks_cached() > *budget {
                        return Err(format!(
                            "cache {} blocks over budget {budget}",
                            pc.blocks_cached()
                        ));
                    }
                }
                pc.clear(Some(&mut ba));
                for ids in &mut seqs {
                    for id in ids.drain(..) {
                        ba.release(id);
                    }
                }
                if ba.in_use() != 0 {
                    return Err(format!("{} blocks leaked", ba.in_use()));
                }
                Ok(())
            },
        );
    }

    // -----------------------------------------------------------------
    // swap tier (DESIGN.md §Overload)

    /// Stash/take round-trips the snapshot bitwise, the block budget
    /// gates admission, 0 means unbounded, and discard drops a shed
    /// sequence's entry without counting as a restore.
    #[test]
    fn swap_tier_budget_and_roundtrip() {
        let mut st = SwapTier::new(4, 8); // 4-block budget, 8-token blocks
        let (k, v) = (vec![1.5f32; 24], vec![-2.5f32; 24]);
        // 17 tokens → 3 blocks of 8
        assert!(st.can_stash(17));
        assert!(st.stash(7, 17, k.clone(), v.clone()));
        assert_eq!(st.resident_blocks(), 3);
        assert!(st.contains(7));
        // duplicate ids are rejected
        assert!(!st.stash(7, 1, Vec::new(), Vec::new()));
        // 2 more blocks would exceed the 4-block budget; 1 fits
        assert!(!st.can_stash(9));
        assert!(!st.stash(8, 9, Vec::new(), Vec::new()));
        assert!(st.stash(8, 8, vec![0.0; 8], vec![0.0; 8]));
        assert_eq!((st.resident_blocks(), st.peak_blocks), (4, 4));
        // take returns the exact bytes and frees the budget
        let (tokens, k2, v2) = st.take(7).expect("stashed");
        assert_eq!((tokens, k2, v2), (17, k, v));
        assert_eq!(st.resident_blocks(), 1);
        assert!(st.take(7).is_none(), "take removes the entry");
        // discard (shed path) drops without a restore
        assert!(st.discard(8));
        assert!(!st.discard(8));
        assert_eq!((st.stashes, st.restores), (2, 1));
        assert_eq!(st.peak_blocks, 4, "high-water mark survives drains");
        // unbounded tier never refuses on capacity
        let mut un = SwapTier::new(0, 8);
        assert!(un.can_stash(1_000_000));
        assert!(un.stash(1, 100, vec![0.0; 4], vec![0.0; 4]));
        // empty snapshots are meaningless and rejected
        assert!(!un.stash(2, 0, Vec::new(), Vec::new()));
    }

    /// Issue satellite (admission probe): `peek` returns exactly what
    /// `lookup` would match, with zero side effects — counters, LRU
    /// order, and subsequent eviction decisions are all unchanged by any
    /// number of peeks.
    #[test]
    fn prefix_peek_matches_lookup_without_side_effects() {
        let (block, nl, h, d) = (4, 1, 2, 3);
        let mut pc = PrefixCache::new(block, 8, nl, h, d);
        let toks: Vec<i32> = (100..116).collect();
        assert!(pc.insert(
            &toks[..8],
            snap(nl, 8, h, d, 1.0),
            snap(nl, 8, h, d, -1.0),
            Vec::new(),
            None,
        ));
        assert!(pc.insert(
            &(200..204).collect::<Vec<i32>>(),
            snap(nl, 4, h, d, 2.0),
            snap(nl, 4, h, d, -2.0),
            Vec::new(),
            None,
        ));
        // peek agrees with lookup on hits, tail-guard, and misses
        assert_eq!(pc.peek(&toks), 8);
        assert_eq!(pc.peek(&toks[..8]), 4, "tail of ≥1 token stays unshared");
        assert_eq!(pc.peek(&[9, 9, 9, 9, 9]), 0);
        // ... and none of that touched the counters
        assert_eq!((pc.hits, pc.misses), (0, 0));
        // peeks must not keep entries warm: the 8-token entry stays the
        // LRU victim even after many peeks at it, so inserting past the
        // budget evicts it — a lookup in peek's place would have
        // protected it.
        pc.lookup(&(200..205).collect::<Vec<i32>>()).expect("warm entry");
        for _ in 0..10 {
            assert_eq!(pc.peek(&toks), 8);
        }
        assert!(pc.insert(
            &(300..316).collect::<Vec<i32>>(),
            snap(nl, 16, h, d, 3.0),
            snap(nl, 16, h, d, -3.0),
            Vec::new(),
            None,
        ));
        assert_eq!(pc.peek(&toks), 0, "peeked-only entry was the LRU victim");
        assert_eq!(pc.peek(&(200..205).collect::<Vec<i32>>()), 4);
    }

    /// Concurrency model (loom lane, issue satellite): the
    /// SwapTier↔BlockAllocator evict/retain/restore state machine under
    /// every interleaving of a victim sequence's suspend/resume script
    /// against a prefix-cache client sharing one of its blocks.  The
    /// victim holds blocks {b0, b1} with b0 also pinned by the prefix
    /// cache; thread A evicts (releasing the sequence's refs and
    /// stashing to the tier) then restores (fresh allocation + take);
    /// thread B retains the pinned block into a warm sequence, then
    /// releases the cache pin.  A cache-pinned block must never dangle —
    /// its refcount must cover every model holder at every step, eviction
    /// must free only last-holder blocks, the tier must hold the victim
    /// exactly while suspended, and the pool must drain at the end.
    #[test]
    fn loom_swap_tier_block_allocator_all_interleavings() {
        use crate::analysis::sched::{explore, Op};
        use crate::sched_ops;

        const VICTIM: u64 = 7;
        #[derive(Clone)]
        struct St {
            ba: BlockAllocator,
            tier: SwapTier,
            victim: Vec<usize>, // the suspended sequence's block table
            warm: Vec<usize>,   // a prefix-warm sequence's pins
            cache_pin: Option<usize>,
            suspended: bool,
        }
        let a_ops: Vec<Op<St>> = sched_ops![
            |s: &mut St| {
                // evict: release the victim's refs (the cache pin keeps
                // b0 alive) and stash its KV in the tier
                for id in s.victim.drain(..) {
                    s.ba.release(id);
                }
                assert!(s.tier.stash(VICTIM, 5, vec![0.5; 4], vec![1.5; 4]));
                s.suspended = true;
            },
            |s: &mut St| {
                // restore: take the snapshot back and re-seed into
                // freshly allocated blocks
                let (tokens, _k, _v) =
                    s.tier.take(VICTIM).expect("stashed while suspended");
                assert_eq!(tokens, 5);
                for _ in 0..2 {
                    s.victim.push(s.ba.alloc().expect("cap 4 fits"));
                }
                s.suspended = false;
            },
            |s: &mut St| {
                for id in s.victim.drain(..) {
                    s.ba.release(id);
                }
            },
        ];
        let b_ops: Vec<Op<St>> = sched_ops![
            |s: &mut St| {
                // warm admission retains the cache-pinned block — valid
                // under any interleaving because the cache pin is alive
                // until B's own release op below
                let b0 = s.cache_pin.expect("pin released only by op 2");
                s.ba.retain(b0);
                s.warm.push(b0);
            },
            |s: &mut St| {
                // prefix-cache eviction: drop the cache's pin
                let b0 = s.cache_pin.take().expect("released once");
                s.ba.release(b0);
            },
            |s: &mut St| {
                for id in s.warm.drain(..) {
                    s.ba.release(id);
                }
            },
        ];
        let mut ba = BlockAllocator::new(4);
        let b0 = ba.alloc().unwrap();
        ba.retain(b0); // prefix-cache pin
        let b1 = ba.alloc().unwrap();
        let n = explore(
            &St {
                ba,
                tier: SwapTier::new(0, 4),
                victim: vec![b0, b1],
                warm: Vec::new(),
                cache_pin: Some(b0),
                suspended: false,
            },
            &[a_ops, b_ops],
            &|s| {
                // refcount == model holders for every block, always
                let mut want = vec![0u32; s.ba.capacity()];
                for &id in s.victim.iter().chain(&s.warm) {
                    want[id] += 1;
                }
                if let Some(id) = s.cache_pin {
                    want[id] += 1;
                }
                for (id, &c) in want.iter().enumerate() {
                    if s.ba.ref_count(id) != c {
                        return Err(format!(
                            "block {id}: refcount {} != holders {c}",
                            s.ba.ref_count(id)
                        ));
                    }
                }
                if s.ba.free_blocks() + s.ba.in_use() != s.ba.capacity() {
                    return Err("free + in_use != capacity".into());
                }
                if s.suspended != s.tier.contains(VICTIM) {
                    return Err("tier residency out of sync".into());
                }
                Ok(())
            },
            &|s| {
                if s.ba.in_use() != 0 {
                    return Err(format!("{} blocks leaked", s.ba.in_use()));
                }
                if s.tier.entries() != 0 {
                    return Err("tier entry leaked".into());
                }
                Ok(())
            },
        )
        .unwrap_or_else(|v| panic!("{v}"));
        assert_eq!(n, 20, "C(6,3) interleavings of two 3-op scripts");
    }

    // -----------------------------------------------------------------
    // quantized residency (DESIGN.md §Quantized-Residency)

    /// Issue satellite: per-row int8 quantize→dequantize round-trip
    /// error stays within the scale-derived bound `s/2` for adversarial
    /// value ranges — all-equal rows, denormals, a single outlier, and
    /// plain gaussian rows — and the scale is the *smallest* power of
    /// two covering the row (so the bound is tight, not just safe).
    #[test]
    fn prop_quant_round_trip_within_scale_bound() {
        Prop::new(200, 0x0A11_7E57).forall(
            |rng| {
                let d = gen::usize_in(rng, 1, 64);
                let kind = rng.below(4);
                let row: Vec<f32> = match kind {
                    // all-equal (scale must cover the common value)
                    0 => vec![rng.normal() * 10.0; d],
                    // denormal magnitudes (scale clamps at MIN_POSITIVE)
                    1 => (0..d).map(|_| rng.normal() * 1e-41).collect(),
                    // one huge outlier among tiny values
                    2 => {
                        let mut r: Vec<f32> =
                            (0..d).map(|_| rng.normal() * 1e-3).collect();
                        let i = rng.below(d);
                        r[i] = rng.normal() * 1e6;
                        r
                    }
                    _ => (0..d).map(|_| rng.normal()).collect(),
                };
                row
            },
            |row| {
                let mut q = vec![0i8; row.len()];
                let s = quantize_row(row, &mut q);
                let mut deq = vec![0f32; row.len()];
                dequantize_row(&q, s, &mut deq);
                let max_abs = row
                    .iter()
                    .map(|x| x.abs())
                    .filter(|a| a.is_finite())
                    .fold(0f32, f32::max);
                if max_abs == 0.0 {
                    if s != 0.0 || deq.iter().any(|&x| x != 0.0) {
                        return Err("zero row must quantize to zeros".into());
                    }
                    return Ok(());
                }
                // scale covers the row and is the smallest such pow2
                if 127.0 * s < max_abs {
                    return Err(format!("scale {s} too small for {max_abs}"));
                }
                let target = max_abs / 127.0;
                if s > f32::MIN_POSITIVE && s * 0.5 >= target {
                    return Err(format!("scale {s} not minimal for {max_abs}"));
                }
                for (i, (&x, &y)) in row.iter().zip(&deq).enumerate() {
                    if (x - y).abs() > s * 0.5 {
                        return Err(format!(
                            "row[{i}]: |{x} - {y}| > s/2 = {}",
                            s * 0.5
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// Requantizing dequantized values is bitwise lossless (power-of-two
    /// scales + exact 7-bit products), which is what makes canonical
    /// values survive pool→swap→pool and pool→prefix→pool round trips
    /// exactly.
    #[test]
    fn quant_requantize_is_bitwise_lossless() {
        let mut rng = Rng::new(0x1D3);
        for _ in 0..50 {
            let mut row: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
            canonicalize_row(&mut row);
            let once = row.clone();
            canonicalize_row(&mut row);
            assert_eq!(once, row, "canonicalize must be idempotent");
            // QuantBuf round trip of canonical values is exact too
            let qb = QuantBuf::quantize(&once, 4);
            assert_eq!(qb.dequantize(), once);
        }
    }

    /// Non-finite and degenerate rows: all-zero → zero scale and zero
    /// payload; NaN elements quantize to 0 without poisoning the scale;
    /// an infinite element saturates without zeroing its neighbors.
    #[test]
    fn quant_edge_rows() {
        let mut q = vec![0i8; 4];
        assert_eq!(quantize_row(&[0.0; 4], &mut q), 0.0);
        assert_eq!(q, vec![0i8; 4]);

        let s = quantize_row(&[1.0, f32::NAN, -1.0, 0.5], &mut q);
        assert!(s > 0.0);
        assert_eq!(q[1], 0, "NaN element quantizes to 0");
        let mut deq = vec![0f32; 4];
        dequantize_row(&q, s, &mut deq);
        assert!((deq[0] - 1.0).abs() <= s * 0.5);
        assert!((deq[2] + 1.0).abs() <= s * 0.5);

        let s = quantize_row(&[f32::INFINITY, 2.0, -2.0, 0.0], &mut q);
        assert!(s.is_finite() && s > 0.0, "inf is ignored by the scale scan");
        assert_eq!(q[0], 127, "inf saturates");
        dequantize_row(&q, s, &mut deq);
        assert!((deq[1] - 2.0).abs() <= s * 0.5);

        // all-non-finite rows degenerate to the zero row
        assert_eq!(quantize_row(&[f32::NAN, f32::INFINITY], &mut q[..2]), 0.0);
        assert_eq!(&q[..2], &[0, 0]);
    }

    /// An int8 pool fed canonicalized rows reads back *bitwise* what an
    /// f32 pool fed the same canonical rows reads back, across every
    /// read surface (`key_into`/`value_into`, `gather`, `export_dense`,
    /// `export_dense_kv`) — and the dequant-row counter advances only on
    /// the int8 pool.
    #[test]
    fn int8_pool_reads_match_f32_pool_on_canonical_rows() {
        let (h, d, pl, nl, toks) = (2usize, 4usize, 8usize, 2usize, 20usize);
        let mut pf = PagePool::new(h, d, pl);
        let mut pq = PagePool::with_limit_quant(h, d, pl, 0, KvQuant::Int8);
        assert_eq!(pq.quant(), KvQuant::Int8);
        let mut cf = SeqKvCache::new(nl);
        let mut cq = SeqKvCache::new(nl);
        let mut rng = Rng::new(0xCA_0);
        for _ in 0..toks {
            for layer in 0..nl {
                let mut k = row(&mut rng, h * d);
                let mut v = row(&mut rng, h * d);
                for hh in 0..h {
                    canonicalize_row(&mut k[hh * d..(hh + 1) * d]);
                    canonicalize_row(&mut v[hh * d..(hh + 1) * d]);
                }
                cf.append(&mut pf, layer, &k, &v).unwrap();
                cq.append(&mut pq, layer, &k, &v).unwrap();
            }
            cf.commit_token();
            cq.commit_token();
        }
        assert_eq!(pq.dequant_rows(), 0, "writes never dequantize");
        let mut a = vec![0f32; d];
        let mut b = vec![0f32; d];
        for layer in 0..nl {
            for head in 0..h {
                for pos in 0..toks {
                    cf.key_into(&pf, layer, head, pos, &mut a);
                    cq.key_into(&pq, layer, head, pos, &mut b);
                    assert_eq!(a, b, "key L{layer} H{head} P{pos}");
                    cf.value_into(&pf, layer, head, pos, &mut a);
                    cq.value_into(&pq, layer, head, pos, &mut b);
                    assert_eq!(a, b, "value L{layer} H{head} P{pos}");
                    // Off-mode *_into agrees with the borrow accessors
                    cf.key_into(&pf, layer, head, pos, &mut a);
                    assert_eq!(&a[..], cf.key(&pf, layer, head, pos));
                }
            }
        }
        let idx = [0usize, 7, 8, 15, 19];
        let (mut gk_f, mut gv_f) = (vec![0f32; idx.len() * d], vec![0f32; idx.len() * d]);
        let (mut gk_q, mut gv_q) = (vec![0f32; idx.len() * d], vec![0f32; idx.len() * d]);
        cf.gather(&pf, 1, 1, &idx, &mut gk_f, &mut gv_f);
        cq.gather(&pq, 1, 1, &idx, &mut gk_q, &mut gv_q);
        assert_eq!(gk_f, gk_q);
        assert_eq!(gv_f, gv_q);
        let l_max = 24;
        let (mut ek_f, mut ev_f) = (vec![0f32; h * l_max * d], vec![0f32; h * l_max * d]);
        let (mut ek_q, mut ev_q) = (vec![0f32; h * l_max * d], vec![0f32; h * l_max * d]);
        cf.export_dense(&pf, 0, l_max, &mut ek_f, &mut ev_f);
        cq.export_dense(&pq, 0, l_max, &mut ek_q, &mut ev_q);
        assert_eq!(ek_f, ek_q);
        assert_eq!(ev_f, ev_q);
        cf.export_dense_kv(&pf, 0, l_max, h, &mut ek_f, &mut ev_f);
        cq.export_dense_kv(&pq, 0, l_max, h, &mut ek_q, &mut ev_q);
        assert_eq!(ek_f, ek_q);
        assert_eq!(ev_f, ev_q);
        assert_eq!(pf.dequant_rows(), 0, "f32 pool never dequantizes");
        // int8 counter: key_into+value_into (2·nl·h·toks) + gather
        // (2·|idx|) + export_dense (2·h·toks) + export_dense_kv (2·h·toks)
        let want = 2 * (nl * h * toks + idx.len() + 2 * h * toks) as u64;
        assert_eq!(pq.dequant_rows(), want);
        // page accounting is precision-independent
        assert_eq!(pf.in_use_pages(), pq.in_use_pages());
        cq.release(&mut pq);
        assert_eq!(pq.in_use_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "use key_into")]
    fn key_borrow_accessor_panics_under_int8() {
        let mut pool = PagePool::with_limit_quant(2, 4, 8, 0, KvQuant::Int8);
        let mut c = SeqKvCache::new(1);
        c.append(&mut pool, 0, &[1.0; 8], &[2.0; 8]).unwrap();
        c.commit_token();
        let _ = c.key(&pool, 0, 0, 0);
    }

    /// Issue satellite: the SwapTier running block counter equals the
    /// Σ-recompute across a random stash/take/discard schedule (the
    /// debug assertion inside `resident_blocks` cross-checks every call).
    #[test]
    fn prop_swap_tier_running_counter_matches_sigma() {
        Prop::new(60, 0x5AB_C0DE).forall(
            |rng| {
                let budget = gen::usize_in(rng, 0, 6);
                let ops: Vec<(u8, u64, usize)> = (0..40)
                    .map(|_| {
                        (rng.below(3) as u8, rng.below(5) as u64,
                         gen::usize_in(rng, 1, 20))
                    })
                    .collect();
                (budget, ops)
            },
            |(budget, ops)| {
                let mut st = SwapTier::new(*budget, 4);
                let mut model: Vec<(u64, usize)> = Vec::new();
                for &(op, id, tokens) in ops {
                    match op {
                        0 => {
                            let n = tokens * 2; // [tokens, H=2, d=1] say
                            if st.stash(id, tokens, vec![0.1; n], vec![0.2; n])
                            {
                                model.push((id, tokens));
                            }
                        }
                        1 => {
                            if st.take(id).is_some() {
                                model.retain(|&(i, _)| i != id);
                            }
                        }
                        _ => {
                            if st.discard(id) {
                                model.retain(|&(i, _)| i != id);
                            }
                        }
                    }
                    let want: usize =
                        model.iter().map(|&(_, t)| t.div_ceil(4)).sum();
                    if st.resident_blocks() != want {
                        return Err(format!(
                            "resident {} != model {want}",
                            st.resident_blocks()
                        ));
                    }
                    if *budget > 0 && st.resident_blocks() > *budget {
                        return Err("budget exceeded".into());
                    }
                }
                Ok(())
            },
        );
    }

    /// An int8 swap tier round-trips canonical snapshots bitwise — the
    /// invariant that keeps preempted-vs-uninterrupted trajectories
    /// identical under quantized residency.
    #[test]
    fn swap_tier_int8_round_trips_canonical_snapshots() {
        let d = 4usize;
        let mut st = SwapTier::with_quant(0, 8, KvQuant::Int8, d);
        let mut rng = Rng::new(0x5AB);
        let mut k: Vec<f32> = (0..3 * 5 * 2 * d).map(|_| rng.normal()).collect();
        let mut v: Vec<f32> = (0..3 * 5 * 2 * d).map(|_| rng.normal()).collect();
        for r in 0..k.len() / d {
            canonicalize_row(&mut k[r * d..(r + 1) * d]);
            canonicalize_row(&mut v[r * d..(r + 1) * d]);
        }
        assert!(st.stash(1, 5, k.clone(), v.clone()));
        let (tokens, k2, v2) = st.take(1).expect("stashed");
        assert_eq!(tokens, 5);
        assert_eq!(k2, k, "canonical K must round-trip bitwise");
        assert_eq!(v2, v, "canonical V must round-trip bitwise");
    }

    /// An int8 prefix cache hands back canonical snapshots bitwise via
    /// `entry_row_into`, agreeing with an f32 cache fed the same rows
    /// (and with the Off-mode borrow accessor).
    #[test]
    fn prefix_cache_int8_entry_rows_match_f32() {
        let (block, nl, h, d) = (4usize, 2usize, 2usize, 3usize);
        let mut pf = PrefixCache::new(block, 16, nl, h, d);
        let mut pq =
            PrefixCache::with_quant(block, 16, nl, h, d, KvQuant::Int8);
        let toks: Vec<i32> = (0..8).collect();
        let mut rng = Rng::new(0x9E1);
        let mut k: Vec<f32> =
            (0..nl * toks.len() * h * d).map(|_| rng.normal()).collect();
        let mut v: Vec<f32> =
            (0..nl * toks.len() * h * d).map(|_| rng.normal()).collect();
        for r in 0..k.len() / d {
            canonicalize_row(&mut k[r * d..(r + 1) * d]);
            canonicalize_row(&mut v[r * d..(r + 1) * d]);
        }
        assert!(pf.insert(&toks, k.clone(), v.clone(), Vec::new(), None));
        assert!(pq.insert(&toks, k, v, Vec::new(), None));
        let w = h * d;
        let (mut ka, mut va) = (vec![0f32; w], vec![0f32; w]);
        let (mut kb, mut vb) = (vec![0f32; w], vec![0f32; w]);
        for layer in 0..nl {
            for pos in 0..toks.len() {
                pf.entry_row_into(0, layer, pos, &mut ka, &mut va);
                pq.entry_row_into(0, layer, pos, &mut kb, &mut vb);
                assert_eq!(ka, kb, "K L{layer} P{pos}");
                assert_eq!(va, vb, "V L{layer} P{pos}");
                let (kr, vr) = pf.entry_row(0, layer, pos);
                assert_eq!(kr, &ka[..]);
                assert_eq!(vr, &va[..]);
            }
        }
    }
}
