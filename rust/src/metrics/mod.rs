//! Serving metrics: latency histograms, throughput counters, retrieval
//! ratio (ρ̂) tracking, and the analytic FLOP model used by the
//! efficiency harnesses.

use std::time::Duration;

/// Streaming latency histogram with exact percentile queries over a
/// bounded reservoir (fine for harness-scale runs).
///
/// Percentile queries sort into a cached buffer that is invalidated on
/// `record`, so a multi-percentile report (p50/p95/p99 inside `prhs
/// serve` reporting) sorts once instead of clone-and-sorting the full
/// reservoir per query.
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples_us: Vec<f64>,
    /// Sorted copy of `samples_us`; valid iff `!dirty`.
    sorted: Vec<f64>,
    dirty: bool,
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
        self.dirty = true;
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn percentile_us(&mut self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        if self.dirty || self.sorted.len() != self.samples_us.len() {
            self.sorted.clone_from(&self.samples_us);
            // total_cmp: a NaN sample (e.g. a poisoned timer delta) must
            // not panic the report path — NaNs sort to the top and only
            // perturb the extreme percentiles they'd dominate anyway
            self.sorted.sort_by(f64::total_cmp);
            self.dirty = false;
        }
        // clamp: out-of-range p (and a NaN p, which saturates to 0 via
        // the `as usize` cast) answers with the nearest extreme instead
        // of indexing out of bounds
        let n = self.sorted.len() as f64;
        let idx = (((n - 1.0) * p / 100.0).round().clamp(0.0, n - 1.0))
            as usize;
        self.sorted[idx]
    }
}

/// Attention FLOP model (per decode step, per layer, per sequence).
/// Score + aggregate FLOPs for n attended entries with head dim d and H
/// heads: 2·H·n·d (QKᵀ) + 2·H·n·d (PV) = 4·H·n·d.
pub fn attn_flops(n_attended: usize, n_heads: usize, head_dim: usize) -> u64 {
    4 * n_heads as u64 * n_attended as u64 * head_dim as u64
}

/// Decode-phase retrieval ratio ρ̂ = (R_total − R_prefill) / head-steps.
///
/// `prefill_retrievals` is the selector's counter snapshotted at prefill
/// completion; `head_steps` = H · n_layers · decode_steps.  This is the
/// paper's R_t accounting (Sec. III, DESIGN.md §4): prefill-side scoring
/// must not be charged against decode head-steps.
pub fn decode_rho_hat(
    total_retrievals: u64,
    prefill_retrievals: u64,
    head_steps: u64,
) -> f64 {
    if head_steps == 0 {
        return 0.0;
    }
    total_retrievals.saturating_sub(prefill_retrievals) as f64
        / head_steps as f64
}

/// Retrieval (full-scoring) FLOPs: 2·H·L·d per scoring pass, scaled by the
/// selector's surrogate cost factor (e.g. DS scores r of d channels).
pub fn retrieval_flops(
    l_context: usize,
    n_heads: usize,
    head_dim: usize,
    cost_factor: f64,
) -> u64 {
    (2.0 * n_heads as f64 * l_context as f64 * head_dim as f64 * cost_factor)
        as u64
}

/// Aggregated per-run serving metrics.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub prefill_lat: Histogram,
    pub step_lat: Histogram,
    /// Time-to-first-token per request: submission → first sampled token
    /// (i.e. prefill completion under chunked prefill, DESIGN.md §6a).
    pub ttft_lat: Histogram,
    pub tokens_out: u64,
    /// Prompt tokens executed in the scheduler's prefill stage (chunk
    /// sizes summed; bounded per iteration by
    /// `EngineConfig::prefill_token_budget`).
    pub prefill_tokens: u64,
    /// Host↔device bytes staged for prefill artifacts, mirrored from
    /// `StepStats::prefill_host_bytes_staged` — O(chunk) per chunk with
    /// `EngineConfig::device_prefill_kv`, ∝ context tile per chunk on
    /// the host-staged paths (DESIGN.md §6a).
    pub prefill_host_bytes: u64,
    /// Prompt tokens the engine actually ran transformer layers over
    /// during prefill, mirrored from
    /// `StepStats::prefill_tokens_executed` — on a prefix-cache hit this
    /// drops to the unshared-tail length (DESIGN.md §Serving).
    pub prefill_tokens_executed: u64,
    /// Prompt tokens seeded from the shared-prefix cache instead of being
    /// prefilled, mirrored from `StepStats::prefix_hit_tokens`.
    pub prefix_hit_tokens: u64,
    /// Device KV blocks adopted by reference (`BlockAllocator::retain`)
    /// from the prefix cache, mirrored from
    /// `StepStats::prefix_hit_blocks` — shared, never copied.
    pub prefix_hit_blocks: u64,
    /// Host↔device bytes staged for decode artifacts, mirrored from
    /// `StepStats::decode_host_bytes_staged` — with
    /// `EngineConfig::device_decode_kv` the dense/retrieval KV rides the
    /// per-sequence device mirror and retrieval staging is
    /// O(N_sel + probs row) per step instead of carrying the ∝ L
    /// context-tile upload of the host-staged oracle (DESIGN.md §2).
    pub decode_host_bytes: u64,
    /// Dense/full-scoring layer passes, mirrored from
    /// `StepStats::dense_layer_calls` (same count on both residency
    /// modes: one per layer with any dense-needing sequence).
    pub dense_calls: u64,
    /// Decode device-residency PJRT dispatches, mirrored from
    /// `StepStats::decode_dev_dispatches` — O(#mirror-groups) per step
    /// with `EngineConfig::batched_decode_dispatch`, O(#sequences) on
    /// the per-seq fallback (DESIGN.md §2).
    pub decode_dev_dispatches: u64,
    /// Retrieval/probe probs-download bytes, mirrored from
    /// `StepStats::decode_probs_bytes` — O(N_sel) per retrieval under
    /// the batched path's in-graph top-k, ∝ L on full-row paths.
    pub decode_probs_bytes: u64,
    /// Bytes copied re-homing device KV residency (tile-path bucket
    /// growth / group moves), mirrored from `StepStats::kv_rehome_bytes`
    /// — pinned to 0 by the paged pool, where sequences grow
    /// block-at-a-time through their block table (DESIGN.md §2).
    pub kv_rehome_bytes: u64,
    /// Peak live physical blocks in the paged device KV pool, mirrored
    /// from `StepStats::device_blocks_live` — Θ(live tokens / block)
    /// exactly (Σ ⌈len/block⌉), vs the whole-tile padded footprint of
    /// the grouped-mirror layout.
    pub device_blocks_live: u64,
    /// Sequences suspended by the overload subsystem, mirrored from
    /// `StepStats::preemptions` (DESIGN.md §Overload).
    pub preemptions: u64,
    /// Paged-pool blocks handed back by suspensions, mirrored from
    /// `StepStats::swap_out_blocks`.
    pub swap_out_blocks: u64,
    /// Host bytes snapshotted into the swap tier (host-depth
    /// suspensions), mirrored from `StepStats::swap_out_bytes` —
    /// `swap_model::swap_kv_bytes` per victim, exactly.
    pub swap_out_bytes: u64,
    /// Host bytes restaged out of the swap tier on resume, mirrored
    /// from `StepStats::swap_in_bytes`; equals `swap_out_bytes` once
    /// every suspended sequence resumed (conservation).
    pub swap_in_bytes: u64,
    /// Device-depth resumes (host pool never drained; mirror re-seeds
    /// lazily), mirrored from `StepStats::restores_reseed`.
    pub restores_reseed: u64,
    /// Host-depth resumes (snapshot restaged into pool pages),
    /// mirrored from `StepStats::restores_restage`.
    pub restores_restage: u64,
    /// KV-pressure events the scheduler resolved by preemption,
    /// deferral, or shedding, mirrored from
    /// `StepStats::kv_pressure_events` — the overload gauge.
    pub kv_pressure_events: u64,
    /// Requests shed with `RejectReason::Preempted` (the swap budget
    /// could not hold their state) — 0 is the exhaustion test's
    /// no-client-visible-failure criterion.
    pub shed_requests: u64,
    /// Host bytes the engine's page pool holds allocated, mirrored from
    /// `StepStats::kv_resident_bytes` (computed through
    /// `model::kv_bytes::pool_bytes` at `EngineConfig::kv_quant`'s
    /// precision — ~3.6× lower under `int8` at d = 32; DESIGN.md
    /// §Quantized-Residency).  Peak over the run.
    pub kv_resident_bytes: u64,
    /// Rows dequantized out of the int8 host pool into f32 staging
    /// paths, mirrored from `StepStats::dequant_rows` — always 0 at
    /// `kv_quant = off`; the dequant-work gauge for the selector's
    /// sketch-scoring path.
    pub dequant_rows: u64,
    pub wall_s: f64,
    /// Decode-phase head-level retrievals only (prefill-side scoring is
    /// excluded from ρ̂ by definition — paper Sec. III, DESIGN.md §4).
    pub retrievals: u64,
    pub head_steps: u64,
}

impl RunMetrics {
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.wall_s
    }

    pub fn rho_hat(&self) -> f64 {
        if self.head_steps == 0 {
            return 0.0;
        }
        self.retrievals as f64 / self.head_steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::default();
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean_us() - 50.5).abs() < 1e-9);
        assert!((h.percentile_us(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile_us(99.0) - 99.0).abs() <= 1.0);
    }

    /// Regression (issue satellite 3): repeated queries must agree with
    /// each other and with a fresh sort, and records between queries must
    /// invalidate the sorted cache.
    #[test]
    fn histogram_cached_percentiles_stay_exact() {
        let mut h = Histogram::default();
        // reverse order exercises the sort; interleave queries + records
        for i in (1..=50).rev() {
            h.record_us(i as f64);
        }
        let p50_a = h.percentile_us(50.0);
        let p50_b = h.percentile_us(50.0);
        assert_eq!(p50_a, p50_b, "repeated queries agree");
        assert_eq!(h.percentile_us(0.0), 1.0);
        assert_eq!(h.percentile_us(100.0), 50.0);
        // a new max must show up in the next query (cache invalidated)
        h.record_us(1000.0);
        assert_eq!(h.percentile_us(100.0), 1000.0);
        assert_eq!(h.percentile_us(0.0), 1.0);
        // clone carries the cache state coherently
        let mut c = h.clone();
        c.record_us(0.5);
        assert_eq!(c.percentile_us(0.0), 0.5);
        assert_eq!(h.percentile_us(0.0), 1.0, "original unaffected");
    }

    /// Regression (issue satellite): a NaN sample used to panic the
    /// sort (`partial_cmp().unwrap()`); `total_cmp` sorts it to the top
    /// and every query still answers.
    #[test]
    fn histogram_survives_nan_samples() {
        let mut h = Histogram::default();
        h.record_us(3.0);
        h.record_us(f64::NAN);
        h.record_us(1.0);
        h.record_us(2.0);
        // no panic, and finite percentiles are untouched by the NaN
        assert_eq!(h.percentile_us(0.0), 1.0);
        assert_eq!(h.percentile_us(50.0), 2.0);
        // NaN sorts above every finite value → p100 reports it
        assert!(h.percentile_us(100.0).is_nan());
        assert!(h.mean_us().is_nan(), "mean is honest about poison");
    }

    /// Regression (issue satellite): p > 100 / p < 0 used to index out
    /// of bounds; both must clamp to the nearest extreme, and a NaN p
    /// must not panic either.
    #[test]
    fn histogram_out_of_range_percentile_clamps() {
        let mut h = Histogram::default();
        for i in 1..=10 {
            h.record_us(i as f64);
        }
        assert_eq!(h.percentile_us(150.0), 10.0, "p>100 clamps to max");
        assert_eq!(h.percentile_us(-5.0), 1.0, "p<0 clamps to min");
        assert_eq!(h.percentile_us(1e18), 10.0, "huge p clamps to max");
        assert_eq!(h.percentile_us(f64::NAN), 1.0, "NaN p answers min");
        assert_eq!(h.percentile_us(0.0), 1.0);
        assert_eq!(h.percentile_us(100.0), 10.0);
    }

    #[test]
    fn flop_model_ratios() {
        // sparse/dense attention FLOP ratio == n/L
        let dense = attn_flops(4096, 8, 64);
        let sparse = attn_flops(128, 8, 64);
        assert!((sparse as f64 / dense as f64 - 128.0 / 4096.0).abs() < 1e-9);
        // DS retrieval at r/d = 1/16 costs 1/16 of a dense pass
        let full = retrieval_flops(1024, 8, 64, 1.0);
        let ds = retrieval_flops(1024, 8, 64, 1.0 / 16.0);
        assert_eq!(ds * 16, full);
    }

    #[test]
    fn run_metrics_rates() {
        let m = RunMetrics {
            tokens_out: 100,
            wall_s: 2.0,
            retrievals: 8,
            head_steps: 64,
            ..Default::default()
        };
        assert_eq!(m.throughput_tps(), 50.0);
        assert_eq!(m.rho_hat(), 0.125);
    }
}
