//! Baseline selectors the paper compares against (Sec. V-A):
//! dense, top-k oracle, H2O [25], StreamingLLM [26], Quest [29],
//! Double Sparsity [44], HShare [33].

use crate::config::{SelectorConfig, SelectorKind};
use crate::util::fx;

use super::{select_criteria, KvSelector, PlanKind, SelectedSet, SelectorCtx};

// ---------------------------------------------------------------------------
// Dense (FlashAttention-2 / GPT-Fast baseline)

pub struct DenseSelector {
    empty: Vec<Vec<usize>>,
}

impl DenseSelector {
    pub fn new(_n_layers: usize, n_heads: usize) -> Self {
        DenseSelector { empty: vec![Vec::new(); n_heads] }
    }
}

impl KvSelector for DenseSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::Dense
    }
    fn plan(&mut self, _layer: usize, _ctx: &SelectorCtx<'_>) -> PlanKind {
        PlanKind::DenseOnly
    }
    fn sets(&self, _layer: usize) -> &[Vec<usize>] {
        &self.empty
    }
    fn observe_probs(&mut self, _l: usize, _h: usize, _t: usize, _p: &[f32]) {}
    fn retrievals(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Top-k oracle (Eq. 5): full scoring every step, keep the budget-many
// heaviest entries. Maximal accuracy, maximal retrieval cost.

pub struct OracleSelector {
    cfg: SelectorConfig,
    n_heads: usize,
    sets: Vec<Vec<Vec<usize>>>,
    retrievals: u64,
}

impl OracleSelector {
    pub fn new(cfg: SelectorConfig, n_layers: usize, n_heads: usize) -> Self {
        OracleSelector {
            cfg,
            n_heads,
            sets: vec![vec![Vec::new(); n_heads]; n_layers],
            retrievals: 0,
        }
    }
}

impl KvSelector for OracleSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::TopKOracle
    }

    fn plan(&mut self, _layer: usize, _ctx: &SelectorCtx<'_>) -> PlanKind {
        self.retrievals += self.n_heads as u64;
        PlanKind::Retrieve { heads: vec![true; self.n_heads] }
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    fn observe_probs(&mut self, layer: usize, head: usize, t: usize, probs: &[f32]) {
        // Pure top-N over cached positions — the argmax of retained mass.
        let budget = self.cfg.budget().min(t);
        let mut idx = fx::top_k_indices(&probs[..t], budget);
        idx.sort_unstable();
        self.sets[layer][head] = idx;
    }

    /// Pure global top-budget: the top `budget()` entries decide the set.
    fn probs_topk_budget(&self) -> Option<usize> {
        Some(self.cfg.budget())
    }

    fn retrievals(&self) -> u64 {
        self.retrievals
    }
}

// ---------------------------------------------------------------------------
// H2O heavy-hitter oracle (TDO): accumulate observed attention over the
// retained set; evict the lowest-scoring non-local entry when over budget.
// Selection itself costs O(1) per step (no scoring pass).

pub struct H2OSelector {
    cfg: SelectorConfig,
    /// Per (layer, head): retained (pos, cumulative score).
    state: Vec<Vec<Vec<(usize, f32)>>>,
    sets: Vec<Vec<Vec<usize>>>,
}

impl H2OSelector {
    pub fn new(cfg: SelectorConfig, n_layers: usize, n_heads: usize) -> Self {
        H2OSelector {
            cfg,
            state: vec![vec![Vec::new(); n_heads]; n_layers],
            sets: vec![vec![Vec::new(); n_heads]; n_layers],
        }
    }

    fn rebuild(&mut self, layer: usize, t: usize) {
        let c_local = self.cfg.c_local;
        for (head, st) in self.state[layer].iter().enumerate() {
            let mut v: Vec<usize> = st.iter().map(|&(p, _)| p).collect();
            // local window always visible
            v.extend(t.saturating_sub(c_local)..t);
            v.sort_unstable();
            v.dedup();
            self.sets[layer][head] = v;
        }
    }
}

impl KvSelector for H2OSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::H2O
    }

    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind {
        self.rebuild(layer, ctx.t);
        PlanKind::Sparse
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    /// Seeding from prefill's last attention row.
    fn observe_probs(&mut self, layer: usize, head: usize, t: usize, probs: &[f32]) {
        let budget = (self.cfg.c_sink + self.cfg.k_middle).min(t);
        let idx = fx::top_k_indices(&probs[..t], budget);
        self.state[layer][head] =
            idx.into_iter().map(|p| (p, probs[p])).collect();
    }

    fn observe_sparse(
        &mut self,
        layer: usize,
        head: usize,
        t: usize,
        set: &[usize],
        probs: &[f32],
    ) {
        let heavy_budget = self.cfg.c_sink + self.cfg.k_middle;
        let st = &mut self.state[layer][head];
        // accumulate observed mass
        for (i, &pos) in set.iter().enumerate() {
            if let Some(e) = st.iter_mut().find(|e| e.0 == pos) {
                e.1 += probs[i];
            }
        }
        // the new token (self slot, last prob) becomes a candidate
        let self_score = probs.last().copied().unwrap_or(0.0);
        if st.iter().all(|e| e.0 != t) {
            st.push((t, self_score));
        }
        // evict lowest-cumulative outside the local window
        let local_start = (t + 1).saturating_sub(self.cfg.c_local);
        while st.len() > heavy_budget {
            let mut min_i = None;
            let mut min_v = f32::INFINITY;
            for (i, &(p, s)) in st.iter().enumerate() {
                if p < local_start && s < min_v {
                    min_v = s;
                    min_i = Some(i);
                }
            }
            match min_i {
                Some(i) => {
                    st.swap_remove(i);
                }
                None => break, // everything is local; nothing evictable
            }
        }
    }

    fn retrievals(&self) -> u64 {
        0 // H2O never performs a scoring pass
    }

    fn needs_sparse_probs(&self) -> bool {
        true // cumulative-attention accounting
    }

    fn scoring_cost_factor(&self) -> f64 {
        0.0
    }
}

// ---------------------------------------------------------------------------
// StreamingLLM: sinks + recency window, zero retrieval.

pub struct StreamingSelector {
    cfg: SelectorConfig,
    sets: Vec<Vec<Vec<usize>>>,
}

impl StreamingSelector {
    pub fn new(cfg: SelectorConfig, n_layers: usize, n_heads: usize) -> Self {
        StreamingSelector { cfg, sets: vec![vec![Vec::new(); n_heads]; n_layers] }
    }
}

impl KvSelector for StreamingSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::StreamingLlm
    }

    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind {
        let t = ctx.t;
        // window sized to the full budget: sinks + (k + local) recent
        let sink_end = self.cfg.c_sink.min(t);
        let win = self.cfg.k_middle + self.cfg.c_local;
        let start = t.saturating_sub(win).max(sink_end);
        for h in 0..self.sets[layer].len() {
            let mut v: Vec<usize> = (0..sink_end).collect();
            v.extend(start..t);
            self.sets[layer][h] = v;
        }
        PlanKind::Sparse
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    fn observe_probs(&mut self, _l: usize, _h: usize, _t: usize, _p: &[f32]) {}

    fn retrievals(&self) -> u64 {
        0
    }

    fn scoring_cost_factor(&self) -> f64 {
        0.0
    }
}

// ---------------------------------------------------------------------------
// Quest (QAA): page-level min/max key summaries; score an upper bound per
// page with the live query; take the best pages up to the budget.

pub struct QuestSelector {
    cfg: SelectorConfig,
    head_dim: usize,
    /// Per (layer, head): per-page elementwise min/max of keys.
    mins: Vec<Vec<Vec<Vec<f32>>>>,
    maxs: Vec<Vec<Vec<Vec<f32>>>>,
    sets: Vec<Vec<Vec<usize>>>,
}

impl QuestSelector {
    pub fn new(
        cfg: SelectorConfig,
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
    ) -> Self {
        QuestSelector {
            cfg,
            head_dim,
            mins: vec![vec![Vec::new(); n_heads]; n_layers],
            maxs: vec![vec![Vec::new(); n_heads]; n_layers],
            sets: vec![vec![Vec::new(); n_heads]; n_layers],
        }
    }

    fn page_bound(q: &[f32], mn: &[f32], mx: &[f32]) -> f32 {
        let mut s = 0.0;
        for i in 0..q.len() {
            s += (q[i] * mn[i]).max(q[i] * mx[i]);
        }
        s
    }
}

impl KvSelector for QuestSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::Quest
    }

    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind {
        let t = ctx.t;
        let page = self.cfg.quest_page;
        let sink_end = self.cfg.c_sink.min(t);
        let local_start = t.saturating_sub(self.cfg.c_local).max(sink_end);
        for (head, q) in ctx.q_heads.iter().enumerate() {
            let mins = &self.mins[layer][head];
            let maxs = &self.maxs[layer][head];
            let n_pages = mins.len();
            let mut scored: Vec<(usize, f32)> = (0..n_pages)
                .filter(|&p| p * page < local_start) // middle pages only
                .map(|p| (p, Self::page_bound(q, &mins[p], &maxs[p])))
                .collect();
            scored.sort_by(|a, b| {
                b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal)
            });
            let pages_needed = self.cfg.k_middle.div_ceil(page);
            let mut v: Vec<usize> = (0..sink_end).collect();
            for &(p, _) in scored.iter().take(pages_needed) {
                let lo = (p * page).max(sink_end);
                let hi = ((p + 1) * page).min(local_start);
                v.extend(lo..hi);
            }
            v.extend(local_start..t);
            v.sort_unstable();
            v.dedup();
            self.sets[layer][head] = v;
        }
        PlanKind::Sparse
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    fn observe_probs(&mut self, _l: usize, _h: usize, _t: usize, _p: &[f32]) {}

    fn observe_new_key(&mut self, layer: usize, head: usize, pos: usize, k: &[f32]) {
        let page = self.cfg.quest_page;
        let pi = pos / page;
        let mins = &mut self.mins[layer][head];
        let maxs = &mut self.maxs[layer][head];
        while mins.len() <= pi {
            mins.push(vec![f32::INFINITY; self.head_dim]);
            maxs.push(vec![f32::NEG_INFINITY; self.head_dim]);
        }
        for i in 0..self.head_dim {
            mins[pi][i] = mins[pi][i].min(k[i]);
            maxs[pi][i] = maxs[pi][i].max(k[i]);
        }
    }

    fn retrievals(&self) -> u64 {
        0
    }

    /// Scoring touches L/page summaries of width 2d → ≈ 2/page of dense.
    fn scoring_cost_factor(&self) -> f64 {
        2.0 / self.cfg.quest_page as f64
    }
}

// ---------------------------------------------------------------------------
// Double Sparsity (QAA): approximate scores with r "label" channels.
// Variant note (DESIGN.md §4): channels are chosen per query as the top-r
// |q| coordinates (the open implementation calibrates offline; the q-aware
// variant needs no calibration corpus and has identical cost r/d · T).

pub struct DsSelector {
    cfg: SelectorConfig,
    head_dim: usize,
    /// Own copy of keys per (layer, head): flat [pos * d].
    keys: Vec<Vec<Vec<f32>>>,
    sets: Vec<Vec<Vec<usize>>>,
}

impl DsSelector {
    pub fn new(
        cfg: SelectorConfig,
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
    ) -> Self {
        DsSelector {
            cfg,
            head_dim,
            keys: vec![vec![Vec::new(); n_heads]; n_layers],
            sets: vec![vec![Vec::new(); n_heads]; n_layers],
        }
    }
}

impl KvSelector for DsSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::DoubleSparsity
    }

    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind {
        let t = ctx.t;
        let d = self.head_dim;
        let r = self.cfg.ds_channels.min(d);
        let sink_end = self.cfg.c_sink.min(t);
        let local_start = t.saturating_sub(self.cfg.c_local).max(sink_end);
        for (head, q) in ctx.q_heads.iter().enumerate() {
            let absq: Vec<f32> = q.iter().map(|x| x.abs()).collect();
            let chans = fx::top_k_indices(&absq, r);
            let keys = &self.keys[layer][head];
            let n = (keys.len() / d).min(t);
            let mut scores = vec![f32::NEG_INFINITY; local_start.min(n)];
            for (pos, s) in scores.iter_mut().enumerate().take(local_start.min(n)).skip(sink_end)
            {
                let krow = &keys[pos * d..(pos + 1) * d];
                let mut acc = 0.0;
                for &c in &chans {
                    acc += q[c] * krow[c];
                }
                *s = acc;
            }
            let k_budget = self.cfg.k_middle.min(scores.len());
            let mut v: Vec<usize> = (0..sink_end).collect();
            if k_budget > 0 {
                v.extend(fx::top_k_indices(&scores, k_budget));
            }
            v.extend(local_start..t);
            v.sort_unstable();
            v.dedup();
            self.sets[layer][head] = v;
        }
        PlanKind::Sparse
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    fn observe_probs(&mut self, _l: usize, _h: usize, _t: usize, _p: &[f32]) {}

    fn observe_new_key(&mut self, layer: usize, head: usize, pos: usize, k: &[f32]) {
        let store = &mut self.keys[layer][head];
        let need = (pos + 1) * self.head_dim;
        if store.len() < need {
            store.resize(need, 0.0);
        }
        store[pos * self.head_dim..need].copy_from_slice(k);
    }

    fn retrievals(&self) -> u64 {
        0
    }

    /// r of d channels scored over the full context: r/d of dense.
    fn scoring_cost_factor(&self) -> f64 {
        self.cfg.ds_channels as f64 / self.head_dim as f64
    }
}

// ---------------------------------------------------------------------------
// HShare: stride-based direct index sharing (the PoHS SOTA the paper
// critiques — no similarity gate, no dilation).  At every block start all
// heads retrieve; within the block the retrieved sets are reused verbatim.

pub struct HShareSelector {
    cfg: SelectorConfig,
    n_heads: usize,
    shared: Vec<Vec<SelectedSet>>,
    sets: Vec<Vec<Vec<usize>>>,
    retrievals: u64,
    steps_since_retrieve: Vec<usize>,
    seeded: Vec<bool>,
}

impl HShareSelector {
    pub fn new(cfg: SelectorConfig, n_layers: usize, n_heads: usize) -> Self {
        HShareSelector {
            cfg,
            n_heads,
            shared: vec![vec![SelectedSet::empty(); n_heads]; n_layers],
            sets: vec![vec![Vec::new(); n_heads]; n_layers],
            retrievals: 0,
            steps_since_retrieve: vec![usize::MAX; n_layers],
            seeded: vec![false; n_layers],
        }
    }
}

impl KvSelector for HShareSelector {
    fn kind(&self) -> SelectorKind {
        SelectorKind::HShare
    }

    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind {
        let stride = self.cfg.hshare_stride.max(1);
        let due = !self.seeded[layer]
            || self.steps_since_retrieve[layer] >= stride - 1;
        if due {
            self.steps_since_retrieve[layer] = 0;
            self.seeded[layer] = true;
            self.retrievals += self.n_heads as u64;
            return PlanKind::Retrieve { heads: vec![true; self.n_heads] };
        }
        self.steps_since_retrieve[layer] += 1;
        for h in 0..self.n_heads {
            self.sets[layer][h] = self.shared[layer][h].materialize(
                ctx.t,
                self.cfg.c_sink,
                self.cfg.c_local,
            );
        }
        PlanKind::Sparse
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    fn observe_probs(&mut self, layer: usize, head: usize, t: usize, probs: &[f32]) {
        let s = select_criteria(
            probs,
            t,
            self.cfg.c_sink,
            self.cfg.c_local,
            self.cfg.k_middle,
        );
        self.sets[layer][head] =
            s.materialize(t, self.cfg.c_sink, self.cfg.c_local);
        self.shared[layer][head] = s;
    }

    /// `select_criteria` reads the middle top-k; with at most
    /// c_sink + c_local non-middle entries able to outrank a middle one,
    /// the global top-`budget()` always covers it (DESIGN.md §2).
    fn probs_topk_budget(&self) -> Option<usize> {
        Some(self.cfg.budget())
    }

    fn retrievals(&self) -> u64 {
        self.retrievals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SelectorConfig {
        SelectorConfig {
            c_sink: 2,
            c_local: 4,
            k_middle: 4,
            quest_page: 4,
            ds_channels: 2,
            hshare_stride: 3,
            ..Default::default()
        }
    }

    fn ctx<'a>(t: usize, qs: &'a [Vec<f32>], hidden: &'a [f32]) -> SelectorCtx<'a> {
        SelectorCtx { t, q_heads: qs, q_heads_raw: qs, hidden, last_keys: None }
    }

    #[test]
    fn dense_always_dense() {
        let mut s = DenseSelector::new(2, 2);
        let qs = vec![vec![0.0; 4]; 2];
        assert_eq!(s.plan(0, &ctx(10, &qs, &[])), PlanKind::DenseOnly);
        assert_eq!(s.retrievals(), 0);
    }

    #[test]
    fn oracle_retrieves_every_step_and_takes_top() {
        let mut s = OracleSelector::new(cfg(), 1, 1);
        let qs = vec![vec![0.0; 4]];
        assert!(matches!(
            s.plan(0, &ctx(50, &qs, &[])),
            PlanKind::Retrieve { .. }
        ));
        let mut probs = vec![0.001f32; 51];
        probs[7] = 0.9;
        probs[30] = 0.5;
        s.observe_probs(0, 0, 50, &probs);
        let set = &s.sets(0)[0];
        assert!(set.contains(&7) && set.contains(&30));
        assert_eq!(set.len(), cfg().budget().min(50));
        assert_eq!(s.retrievals(), 1);
    }

    #[test]
    fn h2o_accumulates_and_evicts_lowest() {
        let mut s = H2OSelector::new(cfg(), 1, 1);
        // seed with heavy positions 0..6 (budget c_sink+k=6)
        let mut probs = vec![0.0f32; 21];
        for p in 0..6 {
            probs[p] = 0.5 - p as f32 * 0.05;
        }
        s.observe_probs(0, 0, 20, &probs);
        let qs = vec![vec![0.0; 4]];
        assert_eq!(s.plan(0, &ctx(20, &qs, &[])), PlanKind::Sparse);
        let set0 = s.sets(0)[0].clone();
        assert!(set0.contains(&0) && set0.contains(&16));
        // feed a sparse step where position 5 gets nothing and the new
        // token is heavy → 5 (lowest cumulative, non-local) gets evicted
        let probs_step: Vec<f32> = set0.iter().map(|_| 0.01).chain([0.8]).collect();
        s.observe_sparse(0, 0, 20, &set0, &probs_step);
        let retained: Vec<usize> =
            s.state[0][0].iter().map(|e| e.0).collect();
        assert!(retained.contains(&20), "new token retained");
        assert!(!retained.contains(&5), "lowest-score evicted, got {retained:?}");
    }

    #[test]
    fn streaming_window_shape() {
        let mut s = StreamingSelector::new(cfg(), 1, 1);
        let qs = vec![vec![0.0; 4]];
        s.plan(0, &ctx(100, &qs, &[]));
        let set = &s.sets(0)[0];
        assert!(set.contains(&0) && set.contains(&1)); // sinks
        assert!(set.contains(&99) && set.contains(&92)); // window of k+local=8
        assert!(!set.contains(&50));
        assert_eq!(s.scoring_cost_factor(), 0.0);
    }

    #[test]
    fn quest_selects_hot_pages() {
        let mut s = QuestSelector::new(cfg(), 1, 1, 4);
        // 6 pages of 4; page 3 (pos 12..16) has huge keys aligned with q
        for pos in 0..24 {
            let v = if (12..16).contains(&pos) { 5.0 } else { 0.1 };
            s.observe_new_key(0, 0, pos, &[v, v, v, v]);
        }
        let qs = vec![vec![1.0, 1.0, 1.0, 1.0]];
        s.plan(0, &ctx(24, &qs, &[]));
        let set = &s.sets(0)[0];
        for p in 12..16 {
            assert!(set.contains(&p), "hot page member {p} missing: {set:?}");
        }
        assert!(set.contains(&0) && set.contains(&23));
    }

    #[test]
    fn ds_scores_with_label_channels() {
        let mut s = DsSelector::new(cfg(), 1, 1, 4);
        for pos in 0..30 {
            // position 10: large on channel 0 (the q-heavy channel)
            let k = if pos == 10 {
                [9.0, 0.0, 0.0, 0.0]
            } else {
                [0.0, 0.0, 0.0, 0.1]
            };
            s.observe_new_key(0, 0, pos, &k);
        }
        let qs = vec![vec![5.0, 0.1, 0.1, 0.1]];
        s.plan(0, &ctx(30, &qs, &[]));
        assert!(s.sets(0)[0].contains(&10));
    }

    #[test]
    fn hshare_stride_and_reuse() {
        let mut s = HShareSelector::new(cfg(), 1, 2);
        let qs = vec![vec![0.0; 4]; 2];
        // step 1: block start → retrieve
        assert!(matches!(
            s.plan(0, &ctx(30, &qs, &[])),
            PlanKind::Retrieve { .. }
        ));
        let mut probs = vec![0.001f32; 31];
        probs[9] = 0.9;
        s.observe_probs(0, 0, 30, &probs);
        s.observe_probs(0, 1, 30, &probs);
        // next 2 steps reuse
        assert_eq!(s.plan(0, &ctx(31, &qs, &[])), PlanKind::Sparse);
        assert!(s.sets(0)[0].contains(&9));
        assert_eq!(s.plan(0, &ctx(32, &qs, &[])), PlanKind::Sparse);
        // 4th step: stride 3 reached → retrieve again
        assert!(matches!(
            s.plan(0, &ctx(33, &qs, &[])),
            PlanKind::Retrieve { .. }
        ));
        assert_eq!(s.retrievals(), 4);
    }
}
