//! CIS — Clustered Index Sharing (paper Sec. IV-A) and the CPE composition
//! (CIS + PSAW decode-time filtering; ETF is prefill-only and handled by
//! the engine's prefill parameters).
//!
//! Mechanics per (layer, head):
//!   * blocks of `s` steps enforce temporal adjacency; the first step of a
//!     block retrieves for every head and stores the reference query;
//!   * within a block, a head shares its reference set iff
//!     cos(q_t, q_ref) ≥ τ (Eq. 12; Table VII ablates the space);
//!   * shared sets are dilated: the top-m middle indices add ±r neighbors
//!     (Eq. 13) to cover the Lipschitz centroid drift (Theorems 1–2);
//!   * CPE additionally intersects deep layers' sets with the PSAW window
//!     (Eq. 15).

use crate::config::{SelectorConfig, SelectorKind, SimSpace};
use crate::util::fx;

use super::{
    psaw_filter, psaw_start, select_criteria, KvSelector, PlanKind,
    SelectedSet, SelectorCtx,
};

struct HeadState {
    shared: SelectedSet,
    ref_vec: Vec<f32>,
}

pub struct CisSelector {
    cfg: SelectorConfig,
    n_layers: usize,
    n_heads: usize,
    #[allow(dead_code)]
    head_dim: usize,
    state: Vec<Vec<HeadState>>,
    sets: Vec<Vec<Vec<usize>>>,
    /// step index within the current share block, per layer.
    block_step: Vec<usize>,
    seeded: Vec<bool>,
    retrievals: u64,
    /// Retrieval decisions of the current step (set by `plan`).
    pending_retrieve: Vec<Vec<bool>>,
    /// Diagnostics for the harnesses.
    pub shared_head_steps: u64,
    pub total_head_steps: u64,
}

impl CisSelector {
    pub fn new(
        cfg: SelectorConfig,
        n_layers: usize,
        n_heads: usize,
        head_dim: usize,
    ) -> Self {
        CisSelector {
            cfg,
            n_layers,
            n_heads,
            head_dim,
            state: (0..n_layers)
                .map(|_| {
                    (0..n_heads)
                        .map(|_| HeadState {
                            shared: SelectedSet::empty(),
                            ref_vec: Vec::new(),
                        })
                        .collect()
                })
                .collect(),
            sets: vec![vec![Vec::new(); n_heads]; n_layers],
            block_step: vec![0; n_layers],
            seeded: vec![false; n_layers],
            retrievals: 0,
            pending_retrieve: vec![vec![false; n_heads]; n_layers],
            shared_head_steps: 0,
            total_head_steps: 0,
        }
    }

    fn sim_vec<'a>(&self, ctx: &'a SelectorCtx<'_>, head: usize) -> &'a [f32] {
        match self.cfg.sim_space {
            SimSpace::Query => &ctx.q_heads_raw[head],
            SimSpace::Hidden => ctx.hidden,
            SimSpace::Key => ctx
                .last_keys
                .map(|ks| ks[head].as_slice())
                .unwrap_or(&ctx.q_heads[head]),
        }
    }

    fn psaw_apply(&self, layer: usize, t: usize, set: &mut Vec<usize>) {
        if self.cfg.kind != SelectorKind::Cpe || !psaw_active(&self.cfg) {
            return;
        }
        let ell_s =
            (self.n_layers as f32 * self.cfg.sched_ell_s_frac) as usize;
        let start = psaw_start(
            t,
            layer,
            self.n_layers,
            ell_s,
            self.cfg.psaw_phi,
            self.cfg.psaw_alpha,
        );
        psaw_filter(set, start, self.cfg.c_sink);
    }
}

fn psaw_active(cfg: &SelectorConfig) -> bool {
    cfg.psaw_enabled || cfg.kind == SelectorKind::Cpe
}

impl KvSelector for CisSelector {
    fn kind(&self) -> SelectorKind {
        self.cfg.kind.clone()
    }

    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind {
        let s = self.cfg.block_size.max(1);
        self.total_head_steps += self.n_heads as u64;

        // Block start (or first step after prefill): retrieve all heads.
        let block_start = !self.seeded[layer] || self.block_step[layer] % s == 0;
        if layer == self.n_layers - 1 {
            // advance the block clock once per step (after the last layer
            // plans; every layer shares the same cadence).
        }
        if block_start {
            self.seeded[layer] = true;
            self.retrievals += self.n_heads as u64;
            self.pending_retrieve[layer] = vec![true; self.n_heads];
            for head in 0..self.n_heads {
                let v = self.sim_vec(ctx, head).to_vec();
                self.state[layer][head].ref_vec = v;
            }
            self.bump_block(layer);
            return PlanKind::Retrieve { heads: vec![true; self.n_heads] };
        }

        // Within the block: per-head cosine gate.
        let mut retrieve = vec![false; self.n_heads];
        let mut any = false;
        for head in 0..self.n_heads {
            let sim = fx::cosine(
                self.sim_vec(ctx, head),
                &self.state[layer][head].ref_vec,
            );
            if sim < self.cfg.sim_threshold {
                retrieve[head] = true;
                any = true;
                self.retrievals += 1;
                // refresh the reference so subsequent steps gate against
                // the most recent retrieval (paper: "choose the most
                // recent such j").
                self.state[layer][head].ref_vec =
                    self.sim_vec(ctx, head).to_vec();
            } else {
                self.shared_head_steps += 1;
                let mut set = self.state[layer][head].shared.materialize(
                    ctx.t,
                    self.cfg.c_sink,
                    self.cfg.c_local,
                );
                self.psaw_apply(layer, ctx.t, &mut set);
                self.sets[layer][head] = set;
            }
        }
        self.bump_block(layer);
        if any {
            self.pending_retrieve[layer] = retrieve.clone();
            PlanKind::Retrieve { heads: retrieve }
        } else {
            PlanKind::Sparse
        }
    }

    fn sets(&self, layer: usize) -> &[Vec<usize>] {
        &self.sets[layer]
    }

    fn observe_probs(&mut self, layer: usize, head: usize, t: usize, probs: &[f32]) {
        let mut sel = select_criteria(
            probs,
            t,
            self.cfg.c_sink,
            self.cfg.c_local,
            self.cfg.k_middle,
        );
        sel.dilate(self.cfg.dilate_m(), self.cfg.dilate_radius);
        let mut set =
            sel.materialize(t, self.cfg.c_sink, self.cfg.c_local);
        self.psaw_apply(layer, t, &mut set);
        self.sets[layer][head] = set;
        self.state[layer][head].shared = sel;
    }

    /// `select_criteria` reads the middle top-k (dilation then works on
    /// winners whose values survive intact); with at most
    /// c_sink + c_local non-middle entries able to outrank a middle
    /// one, the global top-`budget()` always covers it (DESIGN.md §2).
    fn probs_topk_budget(&self) -> Option<usize> {
        Some(self.cfg.budget())
    }

    fn retrievals(&self) -> u64 {
        self.retrievals
    }
}

impl CisSelector {
    fn bump_block(&mut self, layer: usize) {
        self.block_step[layer] += 1;
    }

    /// Fraction of head-steps served by sharing (diagnostics).
    pub fn share_ratio(&self) -> f64 {
        if self.total_head_steps == 0 {
            return 0.0;
        }
        self.shared_head_steps as f64 / self.total_head_steps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(kind: SelectorKind) -> SelectorConfig {
        SelectorConfig {
            kind,
            c_sink: 2,
            c_local: 4,
            k_middle: 4,
            block_size: 4,
            sim_threshold: 0.8,
            dilate_m_frac: 0.5,
            dilate_radius: 1,
            ..Default::default()
        }
    }

    fn qh(dir: &[f32]) -> Vec<Vec<f32>> {
        vec![dir.to_vec()]
    }

    #[test]
    fn block_start_retrieves_all_heads() {
        let mut s = CisSelector::new(cfg(SelectorKind::Cis), 1, 2, 4);
        let qs = vec![vec![1.0, 0.0, 0.0, 0.0]; 2];
        let ctx = SelectorCtx { t: 40, q_heads: &qs, q_heads_raw: &qs, hidden: &[], last_keys: None };
        match s.plan(0, &ctx) {
            PlanKind::Retrieve { heads } => assert_eq!(heads, vec![true, true]),
            p => panic!("expected retrieve, got {p:?}"),
        }
        assert_eq!(s.retrievals(), 2);
    }

    #[test]
    fn similar_queries_share_divergent_retrieve() {
        let mut s = CisSelector::new(cfg(SelectorKind::Cis), 1, 1, 4);
        let q0 = qh(&[1.0, 0.0, 0.0, 0.0]);
        let ctx0 = SelectorCtx { t: 40, q_heads: &q0, q_heads_raw: &q0, hidden: &[], last_keys: None };
        s.plan(0, &ctx0); // block start, stores ref
        let mut probs = vec![0.001f32; 41];
        probs[10] = 0.9;
        s.observe_probs(0, 0, 40, &probs);

        // similar query → share
        let q1 = qh(&[0.99, 0.05, 0.0, 0.0]);
        let ctx1 = SelectorCtx { t: 41, q_heads: &q1, q_heads_raw: &q1, hidden: &[], last_keys: None };
        assert_eq!(s.plan(0, &ctx1), PlanKind::Sparse);
        assert!(s.sets(0)[0].contains(&10));
        assert_eq!(s.retrievals(), 1);

        // orthogonal query → per-head retrieval
        let q2 = qh(&[0.0, 1.0, 0.0, 0.0]);
        let ctx2 = SelectorCtx { t: 42, q_heads: &q2, q_heads_raw: &q2, hidden: &[], last_keys: None };
        assert!(matches!(s.plan(0, &ctx2), PlanKind::Retrieve { .. }));
        assert_eq!(s.retrievals(), 2);
    }

    #[test]
    fn dilation_expands_shared_set() {
        let mut s = CisSelector::new(cfg(SelectorKind::Cis), 1, 1, 4);
        let q = qh(&[1.0, 0.0, 0.0, 0.0]);
        let ctx = SelectorCtx { t: 60, q_heads: &q, q_heads_raw: &q, hidden: &[], last_keys: None };
        s.plan(0, &ctx);
        let mut probs = vec![0.001f32; 61];
        probs[20] = 0.9;
        probs[30] = 0.7;
        s.observe_probs(0, 0, 60, &probs);
        let set = &s.sets(0)[0];
        // m = k*0.5 = 2 winners dilated with r=1
        for p in [19, 20, 21, 29, 30, 31] {
            assert!(set.contains(&p), "missing dilated {p}: {set:?}");
        }
    }

    #[test]
    fn new_block_forces_retrieval() {
        let mut s = CisSelector::new(cfg(SelectorKind::Cis), 1, 1, 4);
        let q = qh(&[1.0, 0.0, 0.0, 0.0]);
        let mk = |t| SelectorCtx { t, q_heads: &q, q_heads_raw: &q, hidden: &[], last_keys: None };
        assert!(matches!(s.plan(0, &mk(40)), PlanKind::Retrieve { .. }));
        let probs = vec![0.02f32; 41];
        s.observe_probs(0, 0, 40, &probs);
        assert_eq!(s.plan(0, &mk(41)), PlanKind::Sparse);
        assert_eq!(s.plan(0, &mk(42)), PlanKind::Sparse);
        assert_eq!(s.plan(0, &mk(43)), PlanKind::Sparse);
        // block size 4 exhausted → retrieve
        assert!(matches!(s.plan(0, &mk(44)), PlanKind::Retrieve { .. }));
    }

    #[test]
    fn cpe_filters_deep_layers_with_psaw() {
        let mut c = cfg(SelectorKind::Cpe);
        c.sched_ell_s_frac = 0.0; // ℓs = 0 → deepest layer prunes hardest
        c.psaw_phi = 0.3;
        c.psaw_alpha = 2.0;
        let n_layers = 4;
        let mut s = CisSelector::new(c, n_layers, 1, 4);
        let q = qh(&[1.0, 0.0, 0.0, 0.0]);
        let ctx = SelectorCtx { t: 200, q_heads: &q, q_heads_raw: &q, hidden: &[], last_keys: None };
        s.plan(3, &ctx);
        let mut probs = vec![0.001f32; 201];
        probs[50] = 0.9; // mid-range critical
        s.observe_probs(3, 0, 200, &probs);
        let set = &s.sets(3)[0];
        let p_start = psaw_start(200, 3, n_layers, 0, 0.3, 2.0);
        assert!(p_start > 50, "schedule must prune pos 50 (start={p_start})");
        assert!(!set.contains(&50), "PSAW must drop mid-range at deep layer");
        assert!(set.contains(&0)); // sinks survive
        assert!(set.contains(&199)); // local survives
    }

    #[test]
    fn share_ratio_diagnostic() {
        let mut s = CisSelector::new(cfg(SelectorKind::Cis), 1, 1, 4);
        let q = qh(&[1.0, 0.0, 0.0, 0.0]);
        let mk = |t| SelectorCtx { t, q_heads: &q, q_heads_raw: &q, hidden: &[], last_keys: None };
        s.plan(0, &mk(40));
        s.observe_probs(0, 0, 40, &vec![0.02f32; 41]);
        s.plan(0, &mk(41));
        s.plan(0, &mk(42));
        assert!((s.share_ratio() - 2.0 / 3.0).abs() < 1e-9);
    }
}
