//! KV selectors: the paper's contribution (CIS / PSAW / CPE) and every
//! baseline it compares against (dense, top-k oracle, H2O, StreamingLLM,
//! Quest, Double Sparsity, HShare) behind a single trait.
//!
//! A selector instance is per-sequence state.  The engine drives it per
//! (step, layer):
//!
//!   1. `plan(layer, ctx)` — the selector refreshes its per-head index
//!      sets for this step and tells the engine which execution path to
//!      take (dense-only / retrieve-then-sparse / sparse).
//!   2. On retrieval the engine runs the dense (full-scoring) artifact and
//!      feeds each retrieving head's post-softmax row to `observe_probs`,
//!      after which the refreshed `sets()` drive the sparse TSA step.
//!   3. After every step the engine reports the new token's keys via
//!      `observe_new_key` (Quest page summaries, DS caches) and the sparse
//!      probs via `observe_sparse` (H2O accumulation).
//!
//! Cost accounting: `retrievals()` counts *head-level* full-scoring events
//! (the paper's R_t), from which ρ̂ = R / (H · n_layers · T) is derived.

pub mod baselines;
pub mod cis;

use crate::config::{SelectorConfig, SelectorKind};

/// Per-step context handed to `plan`.
pub struct SelectorCtx<'a> {
    /// Number of cached tokens; the current query's position index.
    pub t: usize,
    /// Per-head RoPE'd query for this layer (computed by the coordinator's
    /// host-side projection; see `model::proj`).  Used for score-based
    /// retrieval (Quest, DS).
    pub q_heads: &'a [Vec<f32>],
    /// Pre-RoPE queries — the similarity space of Eq. 12 (CIS gating).
    pub q_heads_raw: &'a [Vec<f32>],
    /// The layer's input hidden state (similarity-space ablation).
    pub hidden: &'a [f32],
    /// Per-head key of the previous position (similarity-space ablation).
    pub last_keys: Option<&'a [Vec<f32>]>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum PlanKind {
    /// Run only the dense step and use its outputs (dense baseline).
    DenseOnly,
    /// Run the dense step for full scoring (charged to `heads`), feed
    /// probs back, then run the sparse step with refreshed sets.
    Retrieve { heads: Vec<bool> },
    /// Run the sparse step with the current sets.
    Sparse,
}

pub trait KvSelector: Send {
    fn kind(&self) -> SelectorKind;

    /// Decide the execution path for (layer, step) and refresh sets.
    fn plan(&mut self, layer: usize, ctx: &SelectorCtx<'_>) -> PlanKind;

    /// Current per-head index sets for the sparse step (valid after
    /// `plan`).  Sets exclude the current position t (the TSA artifact
    /// appends the self slot in-graph).
    fn sets(&self, layer: usize) -> &[Vec<usize>];

    /// Full post-softmax attention row for a retrieving head.  `probs` has
    /// one entry per cached position 0..t plus the self slot at index t.
    fn observe_probs(&mut self, layer: usize, head: usize, t: usize, probs: &[f32]);

    /// Post-softmax probs over a sparse step's selected set (+ self slot
    /// last).  Default: ignored.
    fn observe_sparse(
        &mut self,
        _layer: usize,
        _head: usize,
        _t: usize,
        _set: &[usize],
        _probs: &[f32],
    ) {
    }

    /// New token's key row for (layer, head) at position `pos`.
    fn observe_new_key(&mut self, _layer: usize, _head: usize, _pos: usize, _k: &[f32]) {}

    /// Whether this selector consumes sparse-step probability rows
    /// (`observe_sparse`).  When false the engine skips the probs
    /// device→host conversion entirely (perf lever).
    fn needs_sparse_probs(&self) -> bool {
        false
    }

    /// Longest prefix of the (value desc, index asc)-ranked retrieval
    /// row this selector's `observe_probs` can decide from, or `None`
    /// when it needs the complete row.  With `Some(req)` within the
    /// batched dense-dev artifact's in-graph top-k width, the engine
    /// downloads the O(N_sel) (index, value) pair instead of the ∝ L
    /// probs row and feeds a reconstructed sparse row (zeros off the
    /// top-k): selection is invariant because the oracle's global top-k
    /// and `select_criteria`'s middle top-k only ever depend on the top
    /// `req` entries under the shared tie order (`fx::top_k_indices` ==
    /// `jax.lax.top_k`; DESIGN.md §2).  Defaults to `None` — an unknown
    /// selector keeps the exact full-row contract.
    fn probs_topk_budget(&self) -> Option<usize> {
        None
    }

    /// Cumulative head-level retrieval count (paper's Σ R_t).
    fn retrievals(&self) -> u64;

    /// Approximate per-retrieval scoring cost relative to dense scoring
    /// (the paper's Comp* column): 1.0 = full q·K over the context.
    fn scoring_cost_factor(&self) -> f64 {
        1.0
    }
}

/// Construct a selector for one sequence.
pub fn build(
    cfg: &SelectorConfig,
    n_layers: usize,
    n_heads: usize,
    head_dim: usize,
) -> Box<dyn KvSelector> {
    match cfg.kind {
        SelectorKind::Dense => Box::new(baselines::DenseSelector::new(n_layers, n_heads)),
        SelectorKind::TopKOracle => {
            Box::new(baselines::OracleSelector::new(cfg.clone(), n_layers, n_heads))
        }
        SelectorKind::H2O => {
            Box::new(baselines::H2OSelector::new(cfg.clone(), n_layers, n_heads))
        }
        SelectorKind::StreamingLlm => {
            Box::new(baselines::StreamingSelector::new(cfg.clone(), n_layers, n_heads))
        }
        SelectorKind::Quest => Box::new(baselines::QuestSelector::new(
            cfg.clone(),
            n_layers,
            n_heads,
            head_dim,
        )),
        SelectorKind::DoubleSparsity => Box::new(baselines::DsSelector::new(
            cfg.clone(),
            n_layers,
            n_heads,
            head_dim,
        )),
        SelectorKind::HShare => {
            Box::new(baselines::HShareSelector::new(cfg.clone(), n_layers, n_heads))
        }
        SelectorKind::Cis | SelectorKind::Cpe => Box::new(cis::CisSelector::new(
            cfg.clone(),
            n_layers,
            n_heads,
            head_dim,
        )),
    }
}

// ---------------------------------------------------------------------------
// shared set-construction helpers (paper Sec. IV-A "Selection Criteria")

/// Build C_t = sinks ∪ middle ∪ local from a full probs row.
///
/// `probs[0..t]` are cached positions (`probs[t]` is the self slot and is
/// ignored — self attention is in-graph).  Middle top-k is taken over
/// `[c_sink, t - c_local)` by descending weight; the returned `middle`
/// preserves that order (needed by dilation's top-m rule).
///
/// Rows may be shorter than `t + 1` (the engine truncates retrieval rows
/// to the dense bucket width); `t` is clamped so only indexable cached
/// positions are ever selected.  An empty row selects nothing.
pub fn select_criteria(
    probs: &[f32],
    t: usize,
    c_sink: usize,
    c_local: usize,
    k: usize,
) -> SelectedSet {
    if probs.is_empty() {
        return SelectedSet::empty();
    }
    let t = t.min(probs.len().saturating_sub(1));
    let sink_end = c_sink.min(t);
    let local_start = t.saturating_sub(c_local).max(sink_end);
    let mut middle: Vec<usize> = Vec::new();
    if local_start > sink_end {
        let region = &probs[sink_end..local_start];
        let top = crate::util::fx::top_k_indices(region, k);
        middle = top.into_iter().map(|i| i + sink_end).collect();
    }
    SelectedSet { t, sink_end, local_start, middle }
}

/// Decomposed selected set (kept structured so dilation + local-window
/// refresh stay cheap as t advances).
#[derive(Clone, Debug)]
pub struct SelectedSet {
    /// Step at which the middle set was retrieved.
    pub t: usize,
    pub sink_end: usize,
    pub local_start: usize,
    /// Middle indices in descending-score order.
    pub middle: Vec<usize>,
}

impl SelectedSet {
    pub fn empty() -> Self {
        SelectedSet { t: 0, sink_end: 0, local_start: 0, middle: Vec::new() }
    }

    /// Dilate the top-m middle indices by ±r (Eq. 13), clipped to the
    /// middle region that existed at retrieval time.
    pub fn dilate(&mut self, m: usize, r: usize) {
        if r == 0 || self.middle.is_empty() {
            return;
        }
        let lo = self.sink_end;
        let hi = self.local_start;
        let winners: Vec<usize> =
            self.middle.iter().take(m).copied().collect();
        for p in winners {
            for dj in 1..=r {
                if p >= dj && p - dj >= lo {
                    self.middle.push(p - dj);
                }
                if p + dj < hi {
                    self.middle.push(p + dj);
                }
            }
        }
        // Dedup while keeping ranking order for the original prefix.
        let mut seen = std::collections::HashSet::new();
        self.middle.retain(|&x| seen.insert(x));
    }

    /// Materialize the full sorted index set at current step `t_now`
    /// (local window slides with t; sinks and middle are frozen).
    pub fn materialize(&self, t_now: usize, c_sink: usize, c_local: usize) -> Vec<usize> {
        let sink_end = c_sink.min(t_now);
        let local_start = t_now.saturating_sub(c_local).max(sink_end);
        let mut out: Vec<usize> = (0..sink_end).collect();
        out.extend(self.middle.iter().copied().filter(|&p| p < local_start));
        out.extend(local_start..t_now);
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// PSAW decode-time window start P_ℓ(t) (Eq. 15).
pub fn psaw_start(
    t: usize,
    layer: usize,
    n_layers: usize,
    ell_s: usize,
    phi: f32,
    alpha: f32,
) -> usize {
    if layer < ell_s {
        return 0;
    }
    let frac = (layer - ell_s) as f32 / ((n_layers - ell_s) as f32).max(1.0);
    let keep = phi.powf(alpha * frac);
    ((1.0 - keep) * t as f32).floor() as usize
}

/// Apply the PSAW mask to a materialized set: drop indices in
/// (c_sink, P_ℓ(t)).
pub fn psaw_filter(set: &mut Vec<usize>, p_start: usize, c_sink: usize) {
    if p_start == 0 {
        return;
    }
    set.retain(|&p| p < c_sink || p >= p_start);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probs_with_peaks(t: usize, peaks: &[(usize, f32)]) -> Vec<f32> {
        let mut p = vec![0.001f32; t + 1];
        for &(i, w) in peaks {
            p[i] = w;
        }
        p
    }

    #[test]
    fn select_criteria_budget_groups() {
        let t = 100;
        let probs = probs_with_peaks(t, &[(50, 0.5), (60, 0.3), (2, 0.4)]);
        let s = select_criteria(&probs, t, 4, 16, 2);
        assert_eq!(s.sink_end, 4);
        assert_eq!(s.local_start, 84);
        assert_eq!(s.middle, vec![50, 60]); // descending by score, sinks excluded
        let m = s.materialize(t, 4, 16);
        assert!(m.contains(&0) && m.contains(&3)); // sinks
        assert!(m.contains(&50) && m.contains(&60));
        assert!(m.contains(&84) && m.contains(&99)); // local
        assert!(!m.contains(&100)); // never includes self
        assert_eq!(m.len(), 4 + 2 + 16);
    }

    #[test]
    fn select_criteria_short_context_takes_everything() {
        let t = 6;
        let probs = vec![0.1; t + 1];
        let s = select_criteria(&probs, t, 4, 16, 8);
        let m = s.materialize(t, 4, 16);
        assert_eq!(m, (0..t).collect::<Vec<_>>());
    }

    #[test]
    fn select_criteria_empty_row_selects_nothing() {
        let s = select_criteria(&[], 0, 4, 16, 8);
        assert_eq!(s.t, 0);
        assert!(s.middle.is_empty());
        assert_eq!(s.materialize(0, 4, 16), Vec::<usize>::new());
        // t > 0 with an empty row must not panic either
        let s = select_criteria(&[], 37, 4, 16, 8);
        assert_eq!(s.materialize(37, 4, 16).len(), 37.min(4 + 16));
        assert!(s.middle.is_empty());
    }

    #[test]
    fn select_criteria_t_zero() {
        // Self-only row: no cached positions, nothing selectable.
        let s = select_criteria(&[1.0], 0, 4, 16, 8);
        assert_eq!(s.t, 0);
        assert_eq!(s.sink_end, 0);
        assert_eq!(s.local_start, 0);
        assert!(s.middle.is_empty());
        assert_eq!(s.materialize(0, 4, 16), Vec::<usize>::new());
    }

    #[test]
    fn select_criteria_truncated_row_clamps_t() {
        // Row shorter than t + 1 (engine truncates to the dense bucket):
        // t clamps to the last indexable position, middle stays in range.
        let mut probs = vec![0.001f32; 33]; // positions 0..32, self at 32
        probs[10] = 0.9;
        let s = select_criteria(&probs, 100, 2, 8, 4);
        assert!(s.t <= 32);
        assert!(s.middle.iter().all(|&p| p < probs.len()));
        assert!(s.middle.contains(&10));
        let m = s.materialize(s.t, 2, 8);
        assert!(m.iter().all(|&p| p < s.t.max(1)));
    }

    #[test]
    fn dilation_adds_neighbors_within_middle_region() {
        let t = 100;
        let probs = probs_with_peaks(t, &[(50, 0.5), (60, 0.3)]);
        let mut s = select_criteria(&probs, t, 4, 16, 2);
        s.dilate(1, 2); // only top-1 (=50) dilated, radius 2
        let m = s.materialize(t, 4, 16);
        for p in [48, 49, 50, 51, 52] {
            assert!(m.contains(&p), "missing {p}");
        }
        assert!(!m.contains(&59) && !m.contains(&61), "60 must not dilate");
    }

    #[test]
    fn dilation_clips_at_region_bounds() {
        let t = 40;
        let probs = probs_with_peaks(t, &[(4, 0.9)]); // at sink boundary
        let mut s = select_criteria(&probs, t, 4, 8, 1);
        s.dilate(1, 3);
        // nothing below sink_end=4 enters middle
        assert!(s.middle.iter().all(|&p| (4..32).contains(&p)));
    }

    #[test]
    fn materialize_slides_local_window() {
        let t0 = 60;
        let probs = probs_with_peaks(t0, &[(30, 0.9)]);
        let s = select_criteria(&probs, t0, 2, 8, 1);
        let m1 = s.materialize(60, 2, 8);
        let m2 = s.materialize(70, 2, 8);
        assert!(m1.contains(&52) && !m1.contains(&62));
        assert!(m2.contains(&62) && m2.contains(&69));
        assert!(m2.contains(&30)); // frozen middle persists
    }

    #[test]
    fn psaw_start_schedule() {
        // below ell_s: no pruning
        assert_eq!(psaw_start(1000, 2, 8, 6, 0.7, 1.0), 0);
        // at ell_s the exponent is 0 -> keep all
        assert_eq!(psaw_start(1000, 6, 8, 6, 0.7, 1.0), 0);
        // top layer keeps phi^alpha fraction
        let p = psaw_start(1000, 8, 8, 6, 0.7, 1.0);
        assert_eq!(p, ((1.0 - 0.7f32) * 1000.0) as usize);
        // monotone in depth
        let a = psaw_start(1000, 7, 8, 6, 0.7, 1.0);
        assert!(a <= p);
    }

    #[test]
    fn psaw_filter_keeps_sinks() {
        let mut set = vec![0, 1, 5, 100, 200, 300];
        psaw_filter(&mut set, 150, 4);
        assert_eq!(set, vec![0, 1, 200, 300]);
    }
}
