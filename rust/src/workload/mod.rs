//! Synthetic workload generators standing in for the paper's evaluation
//! data (GSM8K, CoQA, LongBench; see DESIGN.md §4 for the substitution
//! argument).  Each generator emits token-id sequences with the length
//! profile of the corresponding task plus structured probes (repeated
//! "needle" n-grams) so that retained-mass / overlap / argmax-agreement
//! metrics are informative about long-range retrieval.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: &'static str,
    /// Mean prompt length in tokens.
    pub mean_len: usize,
    /// Uniform jitter around the mean (±).
    pub jitter: usize,
    /// Decode steps to run.
    pub gen_tokens: usize,
}

/// GSM8K-like: short math-ish prompts (~500 tokens per the paper).
pub const GSM8K: WorkloadSpec =
    WorkloadSpec { name: "gsm8k", mean_len: 448, jitter: 128, gen_tokens: 64 };

/// CoQA-like: conversational prompts (~2000 tokens).
pub const COQA: WorkloadSpec =
    WorkloadSpec { name: "coqa", mean_len: 1536, jitter: 384, gen_tokens: 48 };

/// The sixteen LongBench-like task profiles (Table III).  Lengths follow
/// the published per-task averages, clipped to the prefill buckets of the
/// small model.
pub fn longbench_tasks() -> Vec<WorkloadSpec> {
    vec![
        WorkloadSpec { name: "multinews", mean_len: 1800, jitter: 200, gen_tokens: 48 },
        WorkloadSpec { name: "musique", mean_len: 1900, jitter: 120, gen_tokens: 32 },
        WorkloadSpec { name: "hotpotqa", mean_len: 1700, jitter: 256, gen_tokens: 32 },
        WorkloadSpec { name: "qasper", mean_len: 1500, jitter: 300, gen_tokens: 32 },
        WorkloadSpec { name: "2wikimqa", mean_len: 1400, jitter: 256, gen_tokens: 32 },
        WorkloadSpec { name: "repobench-p", mean_len: 1900, jitter: 100, gen_tokens: 48 },
        WorkloadSpec { name: "triviaqa", mean_len: 1300, jitter: 256, gen_tokens: 24 },
        WorkloadSpec { name: "trec", mean_len: 900, jitter: 200, gen_tokens: 16 },
        WorkloadSpec { name: "qmsum", mean_len: 1800, jitter: 150, gen_tokens: 48 },
        WorkloadSpec { name: "narrativeqa", mean_len: 1900, jitter: 100, gen_tokens: 32 },
        WorkloadSpec { name: "govreport", mean_len: 1850, jitter: 120, gen_tokens: 48 },
        WorkloadSpec { name: "lcc", mean_len: 1100, jitter: 300, gen_tokens: 48 },
        WorkloadSpec { name: "passage-count", mean_len: 1600, jitter: 200, gen_tokens: 16 },
        WorkloadSpec { name: "samsum", mean_len: 1000, jitter: 250, gen_tokens: 32 },
        WorkloadSpec { name: "passage-ret", mean_len: 1500, jitter: 200, gen_tokens: 16 },
        WorkloadSpec { name: "multifieldqa", mean_len: 1300, jitter: 250, gen_tokens: 32 },
    ]
}

/// A generated request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub gen_tokens: usize,
    /// Positions of the needle n-gram insertions (probe diagnostics).
    pub needle_positions: Vec<usize>,
}

/// Markov-ish token stream: a small latent-topic chain makes token
/// statistics non-uniform (so attention forms sinks/clusters), and
/// repeated needle n-grams create genuine long-range dependencies.
pub fn generate(spec: &WorkloadSpec, vocab: usize, rng: &mut Rng) -> Request {
    let len = if spec.jitter > 0 {
        spec.mean_len - spec.jitter + rng.below(2 * spec.jitter)
    } else {
        spec.mean_len
    }
    .max(16);

    let n_topics = 8;
    let topic_vocab = vocab / n_topics;
    let mut topic = rng.below(n_topics);
    let mut prompt = Vec::with_capacity(len);
    // BOS-ish sink token
    prompt.push(1i32);
    while prompt.len() < len {
        if rng.f32() < 0.03 {
            topic = rng.below(n_topics);
        }
        // Zipf-ish within the topic: favor low ids.
        let r = rng.f32();
        let off = ((r * r) * topic_vocab as f32) as usize % topic_vocab.max(1);
        prompt.push((2 + topic * topic_vocab + off) as i32 % vocab as i32);
    }

    // Needle: an 8-token n-gram planted early and repeated near the end —
    // retrieval-quality probes look at whether attention reaches back.
    let needle: Vec<i32> =
        (0..8).map(|_| rng.range(2, vocab) as i32).collect();
    let mut needle_positions = Vec::new();
    if len > 64 {
        let early = rng.range(8, len / 4);
        let late = rng.range(3 * len / 4, len - 8);
        for (j, &tok) in needle.iter().enumerate() {
            prompt[early + j] = tok;
            prompt[late + j] = tok;
        }
        needle_positions.push(early);
        needle_positions.push(late);
    }
    Request { prompt, gen_tokens: spec.gen_tokens, needle_positions }
}

/// Multi-turn chat profile (shared-prefix serving traffic, DESIGN.md
/// §Serving): every conversation starts from the same system prompt, and
/// each turn's prompt is the previous turn's full context plus the
/// assistant reply plus a fresh user message — so turn N+1 shares its
/// whole [0, |turn N| + |reply|) prefix with turn N and the prefix cache
/// should collapse its prefill to the unshared tail.
#[derive(Clone, Debug)]
pub struct ChatSpec {
    pub name: &'static str,
    /// Shared system-prompt length in tokens (the cross-conversation
    /// shared prefix).
    pub system_len: usize,
    /// Mean user-message length per turn.
    pub turn_len: usize,
    /// Uniform jitter around `turn_len` (±).
    pub jitter: usize,
    /// User turns per conversation.
    pub turns: usize,
    /// Assistant reply tokens generated per turn.
    pub gen_tokens: usize,
}

pub const CHAT: ChatSpec = ChatSpec {
    name: "chat",
    system_len: 512,
    turn_len: 96,
    jitter: 32,
    turns: 4,
    gen_tokens: 32,
};

/// Markov-ish token body shared by the chat generators (same latent-topic
/// chain as `generate`, without needle planting).
fn token_stream(len: usize, vocab: usize, rng: &mut Rng) -> Vec<i32> {
    let n_topics = 8;
    let topic_vocab = (vocab / n_topics).max(1);
    let mut topic = rng.below(n_topics);
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        if rng.f32() < 0.03 {
            topic = rng.below(n_topics);
        }
        let r = rng.f32();
        let off = ((r * r) * topic_vocab as f32) as usize % topic_vocab;
        out.push((2 + topic * topic_vocab + off) as i32 % vocab as i32);
    }
    out
}

/// The conversation-shared system prompt: BOS sink + `system_len - 1`
/// body tokens.  Call with a fixed-seed `Rng` to share it across
/// conversations (that sharing is what the prefix cache exploits).
pub fn chat_system_prompt(
    spec: &ChatSpec,
    vocab: usize,
    rng: &mut Rng,
) -> Vec<i32> {
    let mut p = vec![1i32];
    p.extend(token_stream(spec.system_len.saturating_sub(1), vocab, rng));
    p
}

/// One user message (`turn_len ± jitter` tokens, at least 1).
pub fn chat_user_turn(
    spec: &ChatSpec,
    vocab: usize,
    rng: &mut Rng,
) -> Vec<i32> {
    let len = if spec.jitter > 0 {
        spec.turn_len.saturating_sub(spec.jitter) + rng.below(2 * spec.jitter)
    } else {
        spec.turn_len
    }
    .max(1);
    token_stream(len, vocab, rng)
}

/// Turn N+1's prompt: turn N's full prompt ++ the assistant reply ++ the
/// next user message.  The shared prefix with turn N is exactly
/// `prev.len() + reply.len()` tokens.
pub fn chat_turn_prompt(
    prev: &[i32],
    reply: &[i32],
    user: &[i32],
) -> Vec<i32> {
    let mut p = Vec::with_capacity(prev.len() + reply.len() + user.len());
    p.extend_from_slice(prev);
    p.extend_from_slice(reply);
    p.extend_from_slice(user);
    p
}

/// Scale a workload's prompt length (harness sweeps).
pub fn scaled(spec: &WorkloadSpec, mean_len: usize) -> WorkloadSpec {
    WorkloadSpec {
        name: spec.name,
        mean_len,
        jitter: (mean_len / 8).max(1),
        gen_tokens: spec.gen_tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_respects_length_profile() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let r = generate(&GSM8K, 8192, &mut rng);
            assert!(r.prompt.len() >= GSM8K.mean_len - GSM8K.jitter);
            assert!(r.prompt.len() < GSM8K.mean_len + GSM8K.jitter);
            assert!(r.prompt.iter().all(|&t| (0..8192).contains(&t)));
        }
    }

    #[test]
    fn needle_is_planted_twice() {
        let mut rng = Rng::new(2);
        let r = generate(&COQA, 8192, &mut rng);
        assert_eq!(r.needle_positions.len(), 2);
        let (a, b) = (r.needle_positions[0], r.needle_positions[1]);
        assert_eq!(&r.prompt[a..a + 8], &r.prompt[b..b + 8]);
        assert!(b > a + 64);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        assert_eq!(
            generate(&GSM8K, 8192, &mut r1).prompt,
            generate(&GSM8K, 8192, &mut r2).prompt
        );
    }

    /// The shared-prefix contract the prefix cache relies on (engine-free):
    /// turn N+1's prompt starts with turn N's prompt ++ turn N's reply,
    /// the system prompt is byte-identical across conversations generated
    /// from the same seed, and all tokens stay in-vocab.
    #[test]
    fn chat_turns_extend_the_previous_context() {
        let vocab = 8192usize;
        let sys = chat_system_prompt(&CHAT, vocab, &mut Rng::new(0xC4A7));
        assert_eq!(sys.len(), CHAT.system_len);
        assert_eq!(sys[0], 1, "BOS sink leads the shared prefix");
        assert_eq!(
            sys,
            chat_system_prompt(&CHAT, vocab, &mut Rng::new(0xC4A7)),
            "system prompt is deterministic per seed — shareable"
        );

        let mut rng = Rng::new(3);
        let mut prompt = sys.clone();
        for turn in 0..CHAT.turns {
            let user = chat_user_turn(&CHAT, vocab, &mut rng);
            assert!(
                user.len() >= CHAT.turn_len - CHAT.jitter
                    && user.len() < CHAT.turn_len + CHAT.jitter
            );
            // a fake assistant reply (the engine supplies real ones)
            let reply: Vec<i32> =
                (0..CHAT.gen_tokens as i32).map(|t| 2 + t).collect();
            let next = chat_turn_prompt(&prompt, &reply, &user);
            let shared = prompt.len() + reply.len();
            assert_eq!(&next[..prompt.len()], &prompt[..]);
            assert_eq!(&next[prompt.len()..shared], &reply[..]);
            assert_eq!(&next[shared..], &user[..]);
            assert!(next.iter().all(|&t| (0..vocab as i32).contains(&t)));
            prompt = next;
            let _ = turn;
        }
        assert_eq!(
            prompt.len(),
            CHAT.system_len + CHAT.turns * CHAT.gen_tokens + {
                // user lengths jitter; recompute them from the same seed
                let mut r = Rng::new(3);
                (0..CHAT.turns)
                    .map(|_| chat_user_turn(&CHAT, vocab, &mut r).len())
                    .sum::<usize>()
            }
        );
    }

    #[test]
    fn sixteen_longbench_tasks() {
        let tasks = longbench_tasks();
        assert_eq!(tasks.len(), 16);
        let names: std::collections::HashSet<_> =
            tasks.iter().map(|t| t.name).collect();
        assert_eq!(names.len(), 16);
    }
}
