//! Synthetic workload generators standing in for the paper's evaluation
//! data (GSM8K, CoQA, LongBench; see DESIGN.md §4 for the substitution
//! argument).  Each generator emits token-id sequences with the length
//! profile of the corresponding task plus structured probes (repeated
//! "needle" n-grams) so that retained-mass / overlap / argmax-agreement
//! metrics are informative about long-range retrieval.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: &'static str,
    /// Mean prompt length in tokens.
    pub mean_len: usize,
    /// Uniform jitter around the mean (±).
    pub jitter: usize,
    /// Decode steps to run.
    pub gen_tokens: usize,
}

/// GSM8K-like: short math-ish prompts (~500 tokens per the paper).
pub const GSM8K: WorkloadSpec =
    WorkloadSpec { name: "gsm8k", mean_len: 448, jitter: 128, gen_tokens: 64 };

/// CoQA-like: conversational prompts (~2000 tokens).
pub const COQA: WorkloadSpec =
    WorkloadSpec { name: "coqa", mean_len: 1536, jitter: 384, gen_tokens: 48 };

/// The sixteen LongBench-like task profiles (Table III).  Lengths follow
/// the published per-task averages, clipped to the prefill buckets of the
/// small model.
pub fn longbench_tasks() -> Vec<WorkloadSpec> {
    vec![
        WorkloadSpec { name: "multinews", mean_len: 1800, jitter: 200, gen_tokens: 48 },
        WorkloadSpec { name: "musique", mean_len: 1900, jitter: 120, gen_tokens: 32 },
        WorkloadSpec { name: "hotpotqa", mean_len: 1700, jitter: 256, gen_tokens: 32 },
        WorkloadSpec { name: "qasper", mean_len: 1500, jitter: 300, gen_tokens: 32 },
        WorkloadSpec { name: "2wikimqa", mean_len: 1400, jitter: 256, gen_tokens: 32 },
        WorkloadSpec { name: "repobench-p", mean_len: 1900, jitter: 100, gen_tokens: 48 },
        WorkloadSpec { name: "triviaqa", mean_len: 1300, jitter: 256, gen_tokens: 24 },
        WorkloadSpec { name: "trec", mean_len: 900, jitter: 200, gen_tokens: 16 },
        WorkloadSpec { name: "qmsum", mean_len: 1800, jitter: 150, gen_tokens: 48 },
        WorkloadSpec { name: "narrativeqa", mean_len: 1900, jitter: 100, gen_tokens: 32 },
        WorkloadSpec { name: "govreport", mean_len: 1850, jitter: 120, gen_tokens: 48 },
        WorkloadSpec { name: "lcc", mean_len: 1100, jitter: 300, gen_tokens: 48 },
        WorkloadSpec { name: "passage-count", mean_len: 1600, jitter: 200, gen_tokens: 16 },
        WorkloadSpec { name: "samsum", mean_len: 1000, jitter: 250, gen_tokens: 32 },
        WorkloadSpec { name: "passage-ret", mean_len: 1500, jitter: 200, gen_tokens: 16 },
        WorkloadSpec { name: "multifieldqa", mean_len: 1300, jitter: 250, gen_tokens: 32 },
    ]
}

/// A generated request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub gen_tokens: usize,
    /// Positions of the needle n-gram insertions (probe diagnostics).
    pub needle_positions: Vec<usize>,
}

/// Markov-ish token stream: a small latent-topic chain makes token
/// statistics non-uniform (so attention forms sinks/clusters), and
/// repeated needle n-grams create genuine long-range dependencies.
pub fn generate(spec: &WorkloadSpec, vocab: usize, rng: &mut Rng) -> Request {
    let len = if spec.jitter > 0 {
        spec.mean_len - spec.jitter + rng.below(2 * spec.jitter)
    } else {
        spec.mean_len
    }
    .max(16);

    let n_topics = 8;
    let topic_vocab = vocab / n_topics;
    let mut topic = rng.below(n_topics);
    let mut prompt = Vec::with_capacity(len);
    // BOS-ish sink token
    prompt.push(1i32);
    while prompt.len() < len {
        if rng.f32() < 0.03 {
            topic = rng.below(n_topics);
        }
        // Zipf-ish within the topic: favor low ids.
        let r = rng.f32();
        let off = ((r * r) * topic_vocab as f32) as usize % topic_vocab.max(1);
        prompt.push((2 + topic * topic_vocab + off) as i32 % vocab as i32);
    }

    // Needle: an 8-token n-gram planted early and repeated near the end —
    // retrieval-quality probes look at whether attention reaches back.
    let needle: Vec<i32> =
        (0..8).map(|_| rng.range(2, vocab) as i32).collect();
    let mut needle_positions = Vec::new();
    if len > 64 {
        let early = rng.range(8, len / 4);
        let late = rng.range(3 * len / 4, len - 8);
        for (j, &tok) in needle.iter().enumerate() {
            prompt[early + j] = tok;
            prompt[late + j] = tok;
        }
        needle_positions.push(early);
        needle_positions.push(late);
    }
    Request { prompt, gen_tokens: spec.gen_tokens, needle_positions }
}

/// Scale a workload's prompt length (harness sweeps).
pub fn scaled(spec: &WorkloadSpec, mean_len: usize) -> WorkloadSpec {
    WorkloadSpec {
        name: spec.name,
        mean_len,
        jitter: (mean_len / 8).max(1),
        gen_tokens: spec.gen_tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_respects_length_profile() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let r = generate(&GSM8K, 8192, &mut rng);
            assert!(r.prompt.len() >= GSM8K.mean_len - GSM8K.jitter);
            assert!(r.prompt.len() < GSM8K.mean_len + GSM8K.jitter);
            assert!(r.prompt.iter().all(|&t| (0..8192).contains(&t)));
        }
    }

    #[test]
    fn needle_is_planted_twice() {
        let mut rng = Rng::new(2);
        let r = generate(&COQA, 8192, &mut rng);
        assert_eq!(r.needle_positions.len(), 2);
        let (a, b) = (r.needle_positions[0], r.needle_positions[1]);
        assert_eq!(&r.prompt[a..a + 8], &r.prompt[b..b + 8]);
        assert!(b > a + 64);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        assert_eq!(
            generate(&GSM8K, 8192, &mut r1).prompt,
            generate(&GSM8K, 8192, &mut r2).prompt
        );
    }

    #[test]
    fn sixteen_longbench_tasks() {
        let tasks = longbench_tasks();
        assert_eq!(tasks.len(), 16);
        let names: std::collections::HashSet<_> =
            tasks.iter().map(|t| t.name).collect();
        assert_eq!(names.len(), 16);
    }
}
