//! # PrHS / CPE — Near-Oracle KV Selection via Pre-hoc Sparsity
//!
//! Reproduction of "Near-Oracle KV Selection via Pre-hoc Sparsity for
//! Long-Context Inference" (CS.LG 2026) as a three-layer Rust + JAX +
//! Pallas serving stack:
//!
//! * **L3 (this crate)** — serving coordinator: request batching, paged KV
//!   cache, the PrHS selector engine (CIS / PSAW / ETF = CPE) and all PoHS
//!   baselines (H2O, StreamingLLM, Quest, Double Sparsity, HShare, top-k
//!   oracle), PJRT runtime, metrics, harnesses for every paper table and
//!   figure.
//! * **L2 (python/compile/model.py, build-time)** — JAX decoder stages
//!   lowered to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels/tsa.py, build-time)** — Pallas TSA
//!   kernel (interpret mode for CPU-PJRT execution).
//!
//! Python never runs on the request path; the rust binary is
//! self-contained once `artifacts/` is built.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod harness;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod selector;
pub mod server;
pub mod theory;
pub mod util;
pub mod workload;
