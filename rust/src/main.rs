//! prhs — CLI entry for the PrHS/CPE serving stack.
//!
//! Subcommands:
//!   serve    run the engine thread + submit a synthetic workload
//!   run      one-shot generation for a synthetic prompt
//!   harness  regenerate a paper table/figure (fig1|fig2|...|table7)
//!   info     print manifest/artifact summary
//!   check    statically verify an artifact set without executing it

use anyhow::Result;
use prhs::config::{EngineConfig, SelectorKind};
use prhs::coordinator::overload::Priority;
use prhs::coordinator::RequestIn;
use prhs::model::proj::SamplingParams;
use prhs::model::Engine;
use prhs::server::SubmitError;
use prhs::util::cli::Cli;
use prhs::util::rng::Rng;
use prhs::workload;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, r)) => (s.clone(), r.to_vec()),
        None => {
            eprintln!("usage: prhs <serve|run|harness|info|check> [flags]  (--help per subcommand)");
            std::process::exit(2);
        }
    };
    match sub.as_str() {
        "info" => info(&rest),
        "check" => check(&rest),
        "run" => run_once(&rest),
        "serve" => serve(&rest),
        "harness" => harness(&rest),
        other => {
            eprintln!("unknown subcommand `{other}`");
            std::process::exit(2);
        }
    }
}

fn base_cli(name: &'static str, about: &'static str) -> Cli {
    Cli::new(name, about)
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("model", "small", "model name from the manifest")
        .flag("selector", "cis", "dense|oracle|h2o|streaming|quest|ds|hshare|cis|cpe")
        .flag("block-size", "8", "CIS/HShare share-block size s")
        .flag("sim-threshold", "0.8", "CIS cosine gate τ")
        .flag("gen", "32", "decode steps per request")
        .flag("seed", "7", "workload seed")
        .switch("no-strict-manifest", "skip the startup contract check (`prhs check`) on the served model")
}

fn engine_from(args: &prhs::util::cli::Args) -> Result<Engine> {
    let mut cfg = EngineConfig::default();
    cfg.artifacts_dir = args.get("artifacts").to_string();
    cfg.model = args.get("model").to_string();
    cfg.selector.kind = SelectorKind::parse(args.get("selector"))
        .ok_or_else(|| anyhow::anyhow!("bad --selector"))?;
    cfg.selector.block_size = args.get_usize("block-size");
    cfg.selector.sim_threshold = args.get_f64("sim-threshold") as f32;
    cfg.max_new_tokens = args.get_usize("gen");
    cfg.strict_manifest = !args.get_bool("no-strict-manifest");
    if cfg.selector.kind == SelectorKind::Cpe {
        cfg.selector.psaw_enabled = true;
        cfg.selector.etf_enabled = true;
    }
    Engine::new(cfg)
}

fn info(rest: &[String]) -> Result<()> {
    let cli = Cli::new("prhs info", "print manifest summary")
        .flag("artifacts", "artifacts", "artifacts directory");
    let args = cli.parse(rest).map_err(anyhow::Error::msg)?;
    let m = prhs::runtime::Manifest::load(args.get("artifacts"))?;
    match m.contract_version {
        Some(v) => println!("contract version {v}"),
        None => println!("contract version: unstamped (pre-contract artifact set)"),
    }
    for (name, mm) in &m.models {
        println!(
            "model {name}: {} layers, d_model {}, {} heads x d{}, vocab {}",
            mm.n_layers, mm.d_model, mm.n_heads, mm.head_dim, mm.vocab_size
        );
        println!("  {} artifacts, {} weights", mm.artifacts.len(), mm.weights.len());
        for stage in ["embed", "lm_head", "layer_step", "layer_step_dense", "layer_step_dense_dev", "layer_step_dense_dev_batch", "layer_step_dense_dev_paged", "kv_append_dev", "kv_append_dev_batch", "kv_append_dev_paged", "kv_slot_write_dev", "state_to_kv", "state_to_kv_paged", "prefill", "prefill_extend", "prefill_extend_dev", "attn_tsa_xla", "attn_tsa_pallas", "attn_dense"] {
            let n = mm.artifacts.iter().filter(|a| a.stage == stage).count();
            if n > 0 {
                println!("    {stage}: {n}");
            }
        }
    }
    Ok(())
}

/// `prhs check [dir]` — statically verify an artifact set: recompute
/// every stage's declared shapes from the manifest's model dims + bucket
/// params, enforce the cross-artifact contract invariants, and confirm
/// the files on disk match — all without executing a single program.
/// Exits 1 if any error-severity diagnostic fires.
fn check(rest: &[String]) -> Result<()> {
    let cli = Cli::new(
        "prhs check",
        "statically verify an artifact set (shape models + contract invariants + files) without executing it",
    )
    .flag("artifacts", "artifacts", "artifacts directory (or pass it positionally)")
    .switch("json", "emit the machine-readable report on stdout")
    .switch("strict-schema", "treat unknown manifest keys as errors (catch python-side schema drift)");
    let args = cli.parse(rest).map_err(anyhow::Error::msg)?;
    let dir = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or_else(|| args.get("artifacts"))
        .to_string();
    let report =
        prhs::analysis::check_artifacts_dir(&dir, args.get_bool("strict-schema"));
    if args.get_bool("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render());
        if !report.has_errors() {
            println!("ok: {dir} passes the static contract check");
        }
    }
    if report.has_errors() {
        std::process::exit(1);
    }
    Ok(())
}

fn run_once(rest: &[String]) -> Result<()> {
    let cli = base_cli("prhs run", "one-shot generation on a synthetic prompt")
        .flag("prompt-len", "448", "synthetic prompt length");
    let args = cli.parse(rest).map_err(anyhow::Error::msg)?;
    let mut engine = engine_from(&args)?;
    let mut rng = Rng::new(args.get_usize("seed") as u64);
    let spec = workload::scaled(&workload::GSM8K, args.get_usize("prompt-len"));
    let req = workload::generate(&spec, engine.mm.vocab_size, &mut rng);
    let mut seq = engine.new_sequence(0, req.prompt.clone());
    seq.max_new = args.get_usize("gen");
    let t0 = std::time::Instant::now();
    let out = engine.generate(&mut seq)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "selector={} prompt={} generated={} tokens in {:.2}s ({:.1} tok/s)",
        args.get("selector"), req.prompt.len(), out.len(), dt,
        out.len() as f64 / dt
    );
    println!(
        "ρ̂={:.4} avg_selected={:.1}",
        engine.retrieval_ratio(&seq, out.len() as u64),
        engine.stats.avg_selected()
    );
    println!("tokens: {:?}...", &out[..out.len().min(16)]);
    Ok(())
}

fn serve(rest: &[String]) -> Result<()> {
    let cli = base_cli("prhs serve", "serve a synthetic batched workload")
        .flag("requests", "8", "number of requests")
        .flag("batch", "8", "max concurrent batch")
        .flag("prompt-len", "448", "synthetic prompt length")
        .flag("prefill-chunk", "0", "chunked-prefill tokens per iteration (0 = whole prompt)")
        .flag("prefill-budget", "0", "max prefill tokens executed per scheduler iteration (0 = unlimited)")
        .flag("max-kv-pages", "0", "KV page-pool cap; requests wait for pages instead of OOMing (0 = unbounded)")
        .switch("prefill-recompute", "use the prefix-recompute chunked-prefill path (parity oracle)")
        .switch("host-prefill-kv", "stage the prefill context through the host each chunk (disable the device-resident prefill KV path)")
        .switch("host-decode-kv", "stage the decode dense/retrieval context through the host each call (disable the device-resident decode KV mirror)")
        .switch("per-seq-decode-dispatch", "dispatch the device decode path one sequence at a time (disable the batched mirror-group dispatch; parity oracle)")
        .switch("tiled-decode-kv", "keep decode KV in whole-tile per-sequence mirrors (disable the paged block pool; parity oracle)")
        .flag("planner-threads", "0", "host-side planner pool width (0/1 = serial)")
        .flag("prefix-cache-blocks", "0", "shared-prefix cache budget in KV blocks (0 = disabled)")
        .flag("temperature", "0.0", "per-request sampling temperature (0 = greedy)")
        .flag("top-k", "0", "per-request top-k sampling cutoff (0 = disabled)")
        .flag("top-p", "1.0", "per-request nucleus sampling mass (1 = disabled)")
        .flag("priority", "default", "priority class stamped on every submitted request: low|normal|high (default = the engine's default-priority)")
        .flag("device-block-cap", "0", "clamp the paged device KV pool to this many blocks — an overcommit knob for exercising preemption (0 = artifact capacity)")
        .flag("swap-budget-blocks", "0", "host swap-tier budget in KV blocks for preempted sequences (0 = unbounded)")
        .flag("kv-quant", "off", "host KV residency precision: off|int8 (int8 stores pool/swap/prefix pages as scaled int8 and scores the selector against the quantized keys)")
        .flag("aging-iters", "64", "scheduler iterations per anti-starvation priority boost (0 = aging off)")
        .switch("no-preemption", "disable decode preemption under KV pressure (pressure falls back to deferral/demotion)")
        .switch("chat", "run the multi-turn chat workload with streamed replies (each turn extends the previous context — exercises the prefix cache)");
    let args = cli.parse(rest).map_err(anyhow::Error::msg)?;
    let mut cfg = EngineConfig::default();
    cfg.artifacts_dir = args.get("artifacts").to_string();
    cfg.model = args.get("model").to_string();
    cfg.selector.kind = SelectorKind::parse(args.get("selector"))
        .ok_or_else(|| anyhow::anyhow!("bad --selector"))?;
    cfg.selector.block_size = args.get_usize("block-size");
    cfg.max_new_tokens = args.get_usize("gen");
    cfg.max_batch = args.get_usize("batch");
    cfg.prefill_chunk = args.get_usize("prefill-chunk");
    cfg.prefill_token_budget = args.get_usize("prefill-budget");
    cfg.max_kv_pages = args.get_usize("max-kv-pages");
    cfg.prefill_recompute = args.get_bool("prefill-recompute");
    cfg.device_prefill_kv = !args.get_bool("host-prefill-kv");
    cfg.device_decode_kv = !args.get_bool("host-decode-kv");
    cfg.batched_decode_dispatch = !args.get_bool("per-seq-decode-dispatch");
    cfg.paged_device_kv = !args.get_bool("tiled-decode-kv");
    cfg.planner_threads = args.get_usize("planner-threads");
    cfg.strict_manifest = !args.get_bool("no-strict-manifest");
    cfg.prefix_cache_blocks = args.get_usize("prefix-cache-blocks");
    cfg.temperature = args.get_f64("temperature") as f32;
    cfg.device_block_cap = args.get_usize("device-block-cap");
    cfg.swap_budget_blocks = args.get_usize("swap-budget-blocks");
    cfg.aging_iters = args.get_usize("aging-iters") as u64;
    cfg.preemption = !args.get_bool("no-preemption");
    cfg.kv_quant = prhs::kvcache::KvQuant::parse(args.get("kv-quant"))
        .ok_or_else(|| {
            anyhow::anyhow!("bad --kv-quant `{}` (off|int8)", args.get("kv-quant"))
        })?;
    let priority = match args.get("priority") {
        "default" => None,
        "low" => Some(Priority::Low),
        "normal" => Some(Priority::Normal),
        "high" => Some(Priority::High),
        other => anyhow::bail!("bad --priority `{other}`"),
    };
    let sampling = SamplingParams {
        temperature: args.get_f64("temperature") as f32,
        top_k: args.get_usize("top-k"),
        top_p: args.get_f64("top-p") as f32,
        ..Default::default()
    };
    // vocab comes from the manifest (read it without building an engine)
    let vocab = prhs::runtime::Manifest::load(args.get("artifacts"))?
        .model(&cfg.model)?
        .vocab_size;
    let server = prhs::server::Server::spawn_with_config(cfg, 64);
    let client = server.client();

    let mut rng = Rng::new(args.get_usize("seed") as u64);
    if args.get_bool("chat") {
        return serve_chat(
            &args, vocab, &client, sampling, priority, &mut rng, server,
        );
    }
    let spec = workload::scaled(&workload::GSM8K, args.get_usize("prompt-len"));
    let n = args.get_usize("requests");
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n as u64)
        .map(|id| {
            let req = workload::generate(&spec, vocab, &mut rng);
            client
                .submit(RequestIn {
                    id,
                    prompt: req.prompt,
                    max_new_tokens: args.get_usize("gen"),
                    sampling: sampling.clone(),
                    priority,
                })
                .expect("submit")
        })
        .collect();
    let mut total_tokens = 0usize;
    let mut rejected = 0usize;
    for rx in rxs {
        let out = rx.recv()?;
        if let Some(reason) = out.rejected {
            rejected += 1;
            println!("req {}: REJECTED ({reason:?})", out.id);
            continue;
        }
        total_tokens += out.tokens.len();
        println!(
            "req {}: {} tokens, prefill {:.1} ms, ttft {:.1} ms, ρ̂ {:.4}",
            out.id,
            out.tokens.len(),
            out.prefill_us / 1e3,
            out.ttft_us / 1e3,
            out.rho_hat
        );
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {} requests / {total_tokens} tokens in {dt:.2}s → {:.1} tok/s{}",
        n - rejected,
        total_tokens as f64 / dt,
        if rejected > 0 {
            format!(" ({rejected} rejected)")
        } else {
            String::new()
        }
    );
    server.shutdown()?;
    Ok(())
}

/// `prhs serve --chat`: multi-turn conversations over a shared system
/// prompt, each turn streamed token-by-token.  Turn N+1's prompt is turn
/// N's full context plus the generated reply plus a fresh user message,
/// so with `--prefix-cache-blocks > 0` every warm turn's prefill
/// collapses to its unshared tail (watch the per-turn prefill column
/// drop after turn 1).
#[allow(clippy::too_many_arguments)]
fn serve_chat(
    args: &prhs::util::cli::Args,
    vocab: usize,
    client: &prhs::server::ClientHandle,
    sampling: SamplingParams,
    priority: Option<Priority>,
    rng: &mut Rng,
    server: prhs::server::Server,
) -> Result<()> {
    let spec = workload::CHAT;
    // the system prompt is seeded independently of --seed so every
    // conversation shares it (that sharing is what the prefix cache
    // exploits across conversations)
    let sys =
        workload::chat_system_prompt(&spec, vocab, &mut Rng::new(0xC4A7));
    let conversations = args.get_usize("requests").max(1);
    let gen = args.get_usize("gen");
    let mut id = 0u64;
    let t0 = std::time::Instant::now();
    let mut total_tokens = 0usize;
    for conv in 0..conversations {
        let mut prompt = sys.clone();
        let mut reply: Vec<i32> = Vec::new();
        for turn in 0..spec.turns {
            let user = workload::chat_user_turn(&spec, vocab, rng);
            prompt = workload::chat_turn_prompt(&prompt, &reply, &user);
            let mut req = RequestIn {
                id,
                prompt: prompt.clone(),
                max_new_tokens: gen,
                sampling: sampling.clone(),
                priority,
            };
            id += 1;
            // backpressure: retry the request verbatim until accepted
            let (trx, frx) = loop {
                match client.submit_streaming(req) {
                    Ok(ch) => break ch,
                    Err(SubmitError::Busy(back)) => {
                        req = back;
                        std::thread::sleep(
                            std::time::Duration::from_millis(1),
                        );
                    }
                    Err(SubmitError::Closed) => {
                        anyhow::bail!("server closed")
                    }
                }
            };
            let mut streamed = 0usize;
            while trx.recv().is_ok() {
                streamed += 1;
            }
            let out = frx.recv()?;
            if let Some(reason) = out.rejected {
                println!("conv {conv} turn {turn}: REJECTED ({reason:?})");
                break;
            }
            total_tokens += out.tokens.len();
            println!(
                "conv {conv} turn {turn}: prompt {} → {} tokens \
                 ({streamed} streamed), prefill {:.1} ms, ttft {:.1} ms",
                prompt.len(),
                out.tokens.len(),
                out.prefill_us / 1e3,
                out.ttft_us / 1e3,
            );
            reply = out.tokens;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "chat: {conversations} conversations x {} turns, {total_tokens} \
         tokens in {dt:.2}s → {:.1} tok/s",
        spec.turns,
        total_tokens as f64 / dt
    );
    server.shutdown()?;
    Ok(())
}

fn harness(rest: &[String]) -> Result<()> {
    let (name, flags) = match rest.split_first() {
        Some((n, f)) if !n.starts_with("--") => (n.clone(), f.to_vec()),
        _ => {
            eprintln!("usage: prhs harness <fig1|fig2|fig4|fig7|fig8|table2|table3|table5|table6|table7|theory|etf_chunk> [flags]");
            std::process::exit(2);
        }
    };
    let cli = Cli::new("prhs harness", "regenerate a paper table/figure")
        .flag("artifacts", "artifacts", "artifacts directory")
        .flag("requests", "2", "requests per workload")
        .flag("gen", "24", "decode steps per request")
        .flag("seed", "7", "workload seed")
        .flag("probe-every", "4", "fidelity probe period")
        .flag("scale", "0.5", "context-length scale for long workloads")
        .flag("batch", "8", "batch size (table5)")
        .switch("quick", "smaller sweep");
    let args = cli.parse(&flags).map_err(anyhow::Error::msg)?;
    prhs::harness::run(&name, &args)
}
