//! Table IV — attention-operator latency across batch sizes and context
//! lengths for every method (`cargo bench --bench table4_latency`).
//!
//! Measures the real AOT operators on the bench-model geometry (H=8,
//! d=64, matching the paper's per-head cost model):
//!   * dense attention (FlashAttention-2 analogue) per (BS, L),
//!   * sparse TSA attention per (BS, N_sel) — xla and Pallas variants,
//! then composes per-method per-step operator cost exactly as each policy
//! schedules them (e.g. CIS pays TSA every step + one full-scoring pass
//! per block of s steps; Quest pays TSA + a page-summary scan; etc.).

use prhs::runtime::{Input, Runtime};
use prhs::util::bench::{Bencher, Report};
use prhs::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("PRHS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let rt = Runtime::new(&dir)?;
    let mm = rt.model("bench")?.clone();
    let (h, d) = (mm.n_heads, mm.head_dim);
    let quick = std::env::args().any(|a| a == "--quick");
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let mut rng = Rng::new(0xBE7C);

    let batches: &[usize] = if quick { &[8] } else { &[8, 16] };
    let ctxs: &[usize] = if quick { &[1024] } else { &[1024, 2048, 4096] };
    let mut report = Report::new("Table IV raw operators (ms)");

    // ---- raw operator measurements -------------------------------------
    let mut dense_ms = std::collections::BTreeMap::new();
    let mut tsa_ms = std::collections::BTreeMap::new();
    for &b in batches {
        for &l in ctxs {
            let art = mm
                .find("attn_dense", &[("batch", b), ("l_max", l)])
                .expect("dense artifact");
            let q = rand_vec(&mut rng, b * h * d);
            let k = rand_vec(&mut rng, b * h * l * d);
            let v = rand_vec(&mut rng, b * h * l * d);
            let lens = vec![l as i32; b];
            let exec = || {
                rt.execute(
                    art,
                    &[
                        Input::F32(&q, vec![b, h, d]),
                        Input::F32(&k, vec![b, h, l, d]),
                        Input::F32(&v, vec![b, h, l, d]),
                        Input::I32(&lens, vec![b]),
                    ],
                )
                .unwrap()
            };
            exec(); // warm compile
            let m = bencher.run(&format!("dense b{b} L{l}"), || {
                exec();
            });
            dense_ms.insert((b, l), m.median_ms());
            report.push(m);
        }
        for n in [128usize, 160, 576] {
            let Some(art) =
                mm.find("attn_tsa_xla", &[("batch", b), ("n_sel", n)])
            else {
                continue;
            };
            let q = rand_vec(&mut rng, b * h * d);
            let k = rand_vec(&mut rng, b * h * n * d);
            let v = rand_vec(&mut rng, b * h * n * d);
            let mask = vec![1.0f32; b * h * n];
            let exec = || {
                rt.execute(
                    art,
                    &[
                        Input::F32(&q, vec![b, h, d]),
                        Input::F32(&k, vec![b, h, n, d]),
                        Input::F32(&v, vec![b, h, n, d]),
                        Input::F32(&mask, vec![b, h, n]),
                    ],
                )
                .unwrap()
            };
            exec();
            let m = bencher.run(&format!("tsa b{b} N{n}"), || {
                exec();
            });
            tsa_ms.insert((b, n), m.median_ms());
            report.push(m);
        }
        // Pallas-kernel variant (interpret-mode lowering of the L1 kernel)
        for n in [128usize, 160] {
            if let Some(art) =
                mm.find("attn_tsa_pallas", &[("batch", b), ("n_sel", n)])
            {
                let q = rand_vec(&mut rng, b * h * d);
                let k = rand_vec(&mut rng, b * h * n * d);
                let v = rand_vec(&mut rng, b * h * n * d);
                let mask = vec![1.0f32; b * h * n];
                let exec = || {
                    rt.execute(
                        art,
                        &[
                            Input::F32(&q, vec![b, h, d]),
                            Input::F32(&k, vec![b, h, n, d]),
                            Input::F32(&v, vec![b, h, n, d]),
                            Input::F32(&mask, vec![b, h, n]),
                        ],
                    )
                    .unwrap()
                };
                exec();
                let m = bencher.run(&format!("tsa-pallas b{b} N{n}"), || {
                    exec();
                });
                report.push(m);
            }
        }
    }
    report.save("results", "table4_raw")?;

    // ---- composed per-method per-step cost (the paper's Table IV) ------
    println!("\n== Table IV (composed; median ms/step; speedup vs dense) ==");
    let mut md = String::from(
        "## Table IV — attention-operator latency (ms/step)\n\n| BS | L | method | ms/step | speedup_vs_dense |\n|---|---|---|---|---|\n",
    );
    for &b in batches {
        for &l in ctxs {
            let dense = dense_ms[&(b, l)];
            let tsa128 = tsa_ms[&(b, 128)];
            let tsa160 = *tsa_ms.get(&(b, 160)).unwrap_or(&tsa128);
            // scan costs (page summaries / label channels) modeled from
            // the dense pass scaled by each policy's cost factor
            let quest_scan = dense * 2.0 / 16.0;
            let ds_scan = dense * 8.0 / 64.0;
            let rows: Vec<(&str, f64)> = vec![
                ("flash(dense)", dense),
                ("h2o", tsa128),
                ("quest", tsa128 + quest_scan),
                ("ds", tsa128 + ds_scan),
                ("hshare-0", tsa128 + dense / 4.0),
                ("hshare-1", tsa128 + dense / 8.0),
                ("cis-8", tsa160 + dense / 8.0),
                ("cis-16", tsa160 + dense / 16.0),
                // CPE: PSAW trims deep-layer sets back to ~the base budget
                ("cpe-8", tsa128 + dense / 8.0),
                ("cpe-16", tsa128 + dense / 16.0),
            ];
            for (name, ms) in rows {
                let speedup = dense / ms;
                println!("  BS{b} L{l} {name:<14} {ms:8.3} ms  ({speedup:5.2}x)");
                md.push_str(&format!(
                    "| {b} | {l} | {name} | {ms:.3} | {speedup:.2} |\n"
                ));
            }
        }
    }
    std::fs::create_dir_all("results")?;
    std::fs::write("results/table4.md", &md)?;
    println!("→ results/table4.md, results/table4_raw.{{md,csv}}");
    Ok(())
}
