//! Table V (bench form) — end-to-end decode throughput through the
//! batched scheduler for a compact method set.  The full sweep lives in
//! `prhs harness table5`; this bench keeps `cargo bench` bounded.

use prhs::config::{EngineConfig, SelectorConfig, SelectorKind};
use prhs::coordinator::{RequestIn, Scheduler};
use prhs::model::Engine;
use prhs::runtime::{Runtime, WeightStore};
use prhs::util::rng::Rng;
use prhs::workload;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("PRHS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let quick = std::env::args().any(|a| a == "--quick");
    let mut base = EngineConfig::default();
    base.artifacts_dir = dir;
    let rt = Arc::new(Runtime::new(&base.artifacts_dir)?);
    let mm = rt.model("small")?.clone();
    let ws = Arc::new(WeightStore::load(&rt, &mm)?);

    let bs = 8usize;
    let ctx = if quick { 256 } else { 768 };
    let gen = if quick { 8 } else { 24 };
    let methods: Vec<(&str, SelectorKind, usize)> = vec![
        ("dense", SelectorKind::Dense, 8),
        ("hshare", SelectorKind::HShare, 8),
        ("cis-16", SelectorKind::Cis, 16),
        ("cpe-16", SelectorKind::Cpe, 16),
    ];
    println!("== Table V bench (BS {bs}, ctx {ctx}, gen {gen}) ==");
    let mut md = String::from(
        "## Table V (bench) — decode throughput\n\n| method | tok/s | step_p50_ms |\n|---|---|---|\n",
    );
    for (name, kind, s) in methods {
        let mut cfg = base.clone();
        cfg.selector = SelectorConfig {
            kind: kind.clone(),
            block_size: s,
            hshare_stride: s,
            psaw_enabled: kind == SelectorKind::Cpe,
            etf_enabled: kind == SelectorKind::Cpe,
            ..Default::default()
        };
        cfg.max_batch = bs;
        cfg.max_new_tokens = gen;
        let engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
        let mut sched = Scheduler::new(engine);
        let mut rng = Rng::new(3);
        let spec = workload::scaled(&workload::GSM8K, ctx);
        for id in 0..bs as u64 {
            let req = workload::generate(&spec, mm.vocab_size, &mut rng);
            sched.submit(RequestIn {
                id,
                prompt: req.prompt,
                max_new_tokens: gen,
                sampling: Default::default(),
                priority: None,
            });
        }
        let outs = sched.run_to_completion()?;
        let toks: usize = outs.iter().map(|o| o.tokens.len()).sum();
        let decode_s = sched.metrics.step_lat.mean_us()
            * sched.metrics.step_lat.count() as f64
            / 1e6;
        let tps = toks as f64 / decode_s.max(1e-9);
        let p50 = sched.metrics.step_lat.percentile_us(50.0) / 1e3;
        println!("  {name:<8} {tps:8.1} tok/s   p50 {p50:6.1} ms/step");
        md.push_str(&format!("| {name} | {tps:.1} | {p50:.1} |\n"));
    }
    std::fs::create_dir_all("results")?;
    std::fs::write("results/table5_bench.md", md)?;
    println!("→ results/table5_bench.md");
    Ok(())
}
