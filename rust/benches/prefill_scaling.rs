//! Prefill + decode residency scaling bench (tentpole regressions):
//! total prefill *compute* must scale with L, not with the sum of
//! prefixes; with the device-resident KV paths the *host bytes staged*
//! must be O(chunk) per prefill chunk (not ∝ start) and O(N_sel + probs
//! row) per decode retrieval (not ∝ L — the context rides the device
//! mirror).
//!
//! For each prompt length L the bench runs a full chunked prefill plus a
//! short decode (CIS retrieves on the first post-prefill step, so the
//! decode phase always exercises the dense/retrieval path) on three
//! paths — device-resident (`prefill_extend_dev` + the decode mirror,
//! the default), host-staged (`device_prefill_kv = device_decode_kv =
//! false`, the parity oracle), and the prefix-recompute compute oracle —
//! reporting wall time, the engine's executed-prompt-token counter, and
//! the `StepStats::{prefill,decode}_host_bytes_staged` counters plus
//! dense-call counts.  Executed tokens are the Θ(L)-vs-Θ(L²/chunk)
//! compute signal; host bytes are the bandwidth-collapse signals
//! (DESIGN.md §2/§6a).  CI compiles this via `cargo bench --no-run` and
//! runs it in the bench-smoke job with `--quick --json
//! results/prefill_scaling.json` (the `BENCH_ci.json` artifact); running
//! it requires `make artifacts`.

use prhs::config::{EngineConfig, SelectorKind};
use prhs::kvcache::KvQuant;
use prhs::model::{kv_bytes, ChunkLedger, Engine};
use prhs::runtime::{Runtime, WeightStore};
use prhs::util::bench::arg_value;
use prhs::util::rng::Rng;
use prhs::workload;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy)]
struct PathRun {
    ms: f64,
    tokens: u64,
    host_bytes: u64,
    decode_ms: f64,
    decode_bytes: u64,
    dense_calls: u64,
    dense_dev_calls: u64,
    /// Decode device-residency PJRT dispatches — O(#mirror-groups) per
    /// step on the batched default, O(#sequences) per-seq (DESIGN.md §2).
    dev_dispatches: u64,
    /// Retrieval/probe probs-download bytes — O(N_sel) per retrieval
    /// under the batched path's in-graph top-k, ∝ L on full-row paths.
    probs_bytes: u64,
    /// Mirror re-home traffic (tile reseeds after a dropped device
    /// mirror).  The paged pool grows by allocation, never by copy, so
    /// this column is pinned to 0 whenever paged artifacts exist.
    rehome_bytes: u64,
    /// Live paged-pool blocks at run end (before release) — the
    /// Θ(live tokens / block) footprint signal.  0 on tile/host paths.
    blocks_live: u64,
    /// Allocated-but-unclaimed slots across live mirror groups at run
    /// end — the whole-tile padding waste the paged layout eliminates
    /// (its analogue is < `block` rows per sequence, inside
    /// `blocks_live`).
    pad_slots: u64,
}

const DECODE_STEPS: usize = 8;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("PRHS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built at {dir}");
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let json_path = arg_value("--json");
    let chunk = 128usize;
    // 1536 is deliberately not bucket-aligned: its prompt leaves
    // headroom in the 2048 buckets, so the device decode run keeps the
    // in-device prefill handoff and the dev-vs-host decode-byte
    // assertion stays pinned in the full sweep too (see
    // `dev_decode_pinned` below).
    let lens: &[usize] =
        if quick { &[256, 512] } else { &[512, 1024, 1536, 2048] };

    let mut base = EngineConfig::default();
    base.artifacts_dir = dir;
    base.selector.kind = SelectorKind::Cis;
    let rt = Arc::new(Runtime::new(&base.artifacts_dir)?);
    let mm = rt.model("small")?.clone();
    let ws = Arc::new(WeightStore::load(&rt, &mm)?);
    let has_dev = !mm.buckets("prefill_extend_dev", "chunk").is_empty();
    let has_dev_decode =
        !mm.buckets("layer_step_dense_dev", "l_max").is_empty();
    let has_paged = !mm.buckets("kv_append_dev_paged", "batched").is_empty();

    println!("== prefill + decode residency scaling (chunk {chunk}) ==");
    let mut md = String::from(
        "## Prefill + decode residency scaling — device-resident vs host-staged vs recompute\n\n\
         | L | dev ms | dev KB staged | dev decode KB | dev probs KB | dev dispatches | dev dense calls | dev rehome KB | dev blocks live | dev pad slots | host ms | host KB staged | host decode KB | host probs KB | host dense calls | recompute ms | recompute tokens |\n\
         |---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n",
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &l in lens {
        // Decode needs dense buckets past the prompt (CIS retrieves on
        // the first post-prefill step and context grows per step); skip
        // the decode phase for rows whose prompt already fills the
        // largest compiled bucket (the quick set's L = 512 row).
        let can_decode = mm
            .bucket_for("layer_step_dense", "l_max", l + DECODE_STEPS)
            .is_some();
        // The dev-vs-host decode-byte assertion is only structurally
        // guaranteed when the device run gets the free in-device
        // prefill→decode handoff — i.e. the prompt does NOT exactly
        // fill its prefill bucket (bucket-aligned rows re-seed the
        // mirror from the host, which can rival the oracle's few dense
        // calls over this short decode; the integration tests pin the
        // collapse rigorously at non-aligned lengths).
        let dev_decode_pinned = can_decode
            && has_dev_decode
            && mm
                .bucket_for("prefill_extend_dev", "l_max", l)
                .is_some_and(|lb| l + DECODE_STEPS <= lb);
        let run = |device: bool, recompute: bool| -> anyhow::Result<PathRun> {
            let mut cfg = base.clone();
            cfg.device_prefill_kv = device;
            cfg.device_decode_kv = device;
            cfg.prefill_recompute = recompute;
            let mut engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
            let mut rng = Rng::new(0x5CA1E);
            let prompt: Vec<i32> =
                (0..l).map(|_| rng.below(mm.vocab_size) as i32).collect();
            let mut seq = engine.new_sequence(0, prompt);
            seq.max_new = DECODE_STEPS;
            let t0 = Instant::now();
            while !engine.prefill_chunk(&mut seq, chunk)? {}
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            // decode phase: CIS retrieves on the first step, so the
            // dense-path residency (mirror vs export_dense) is exercised
            let t1 = Instant::now();
            while can_decode && !seq.done {
                let mut g = [&mut seq];
                engine.decode_step(&mut g)?;
            }
            let decode_ms = t1.elapsed().as_secs_f64() * 1e3;
            let out = PathRun {
                ms,
                tokens: engine.stats.prefill_tokens_executed,
                host_bytes: engine.stats.prefill_host_bytes_staged,
                decode_ms,
                decode_bytes: engine.stats.decode_host_bytes_staged,
                dense_calls: engine.stats.dense_layer_calls,
                dense_dev_calls: engine.stats.decode_dense_dev_calls,
                dev_dispatches: engine.stats.decode_dev_dispatches,
                probs_bytes: engine.stats.decode_probs_bytes,
                rehome_bytes: engine.stats.kv_rehome_bytes,
                blocks_live: engine.stats.device_blocks_live,
                pad_slots: engine.mirror_slot_usage().1 as u64,
            };
            engine.release(&mut seq);
            Ok(out)
        };
        let dev = if has_dev { Some(run(true, false)?) } else { None };
        let host = run(false, false)?;
        let slow = run(false, true)?;
        assert_eq!(
            host.tokens,
            ChunkLedger::executed_tokens(l, chunk, true),
            "KV-in counter must be Θ(L)"
        );
        assert_eq!(
            slow.tokens,
            ChunkLedger::executed_tokens(l, chunk, false),
            "recompute counter must be Θ(L²/chunk)"
        );
        if let Some(d) = dev {
            assert_eq!(d.tokens, host.tokens, "device path is Θ(L) too");
            assert!(
                d.host_bytes < host.host_bytes,
                "device path must stage fewer prefill host bytes"
            );
            if dev_decode_pinned {
                assert!(
                    d.decode_bytes < host.decode_bytes,
                    "device decode must stage fewer host bytes \
                     ({} vs {})",
                    d.decode_bytes,
                    host.decode_bytes
                );
            }
            if can_decode {
                assert_eq!(
                    d.dense_calls, host.dense_calls,
                    "residency must not change how often full scoring runs"
                );
            }
            if has_paged {
                // paged pool grows by allocation, never by copy
                assert_eq!(
                    d.rehome_bytes, 0,
                    "paged device KV must do zero re-home copies"
                );
                assert_eq!(
                    d.pad_slots, 0,
                    "paged mirrors must not hold whole-tile group padding"
                );
                if can_decode {
                    assert!(
                        d.blocks_live > 0,
                        "paged decode must leave a live block footprint"
                    );
                }
            }
        }
        assert_eq!(host.blocks_live, 0, "host path must not touch the pool");
        let (dev_ms, dev_kb, dev_dkb, dev_dc) = dev
            .map(|d| {
                (d.ms, d.host_bytes / 1024, d.decode_bytes / 1024, d.dense_calls)
            })
            .unwrap_or((f64::NAN, 0, 0, 0));
        let (dev_pkb, dev_disp) = dev
            .map(|d| (d.probs_bytes / 1024, d.dev_dispatches))
            .unwrap_or((0, 0));
        let (dev_rkb, dev_blocks, dev_pads) = dev
            .map(|d| (d.rehome_bytes / 1024, d.blocks_live, d.pad_slots))
            .unwrap_or((0, 0, 0));
        println!(
            "  L {l:5}: dev {dev_ms:8.1} ms / {dev_kb:7} KB (+{dev_dkb:6} KB decode, {dev_dc} dense)   \
             host {:8.1} ms / {:7} KB (+{:6} KB decode, {} dense)   recompute {:8.1} ms / {:6} tok",
            host.ms,
            host.host_bytes / 1024,
            host.decode_bytes / 1024,
            host.dense_calls,
            slow.ms,
            slow.tokens,
        );
        md.push_str(&format!(
            "| {l} | {dev_ms:.1} | {dev_kb} | {dev_dkb} | {dev_pkb} | {dev_disp} | {dev_dc} | {dev_rkb} | {dev_blocks} | {dev_pads} | {:.1} | {} | {} | {} | {} | {:.1} | {} |\n",
            host.ms,
            host.host_bytes / 1024,
            host.decode_bytes / 1024,
            host.probs_bytes / 1024,
            host.dense_calls,
            slow.ms,
            slow.tokens
        ));
        json_rows.push(format!(
            "{{\"l\":{l},\"chunk\":{chunk},\"decode_steps\":{DECODE_STEPS},\
             \"dev_ms\":{:.3},\"dev_tokens\":{},\"dev_host_bytes\":{},\
             \"dev_decode_ms\":{:.3},\"dev_decode_host_bytes\":{},\
             \"dev_dense_calls\":{},\"dev_dense_dev_calls\":{},\
             \"dev_dispatches\":{},\"dev_probs_bytes\":{},\
             \"dev_rehome_bytes\":{},\"dev_blocks_live\":{},\
             \"dev_pad_slots\":{},\
             \"host_ms\":{:.3},\"host_tokens\":{},\"host_host_bytes\":{},\
             \"host_decode_ms\":{:.3},\"host_decode_host_bytes\":{},\
             \"host_dense_calls\":{},\"host_probs_bytes\":{},\
             \"recompute_ms\":{:.3},\"recompute_tokens\":{}}}",
            dev.map(|d| d.ms).unwrap_or(-1.0),
            dev.map(|d| d.tokens).unwrap_or(0),
            dev.map(|d| d.host_bytes).unwrap_or(0),
            dev.map(|d| d.decode_ms).unwrap_or(-1.0),
            dev.map(|d| d.decode_bytes).unwrap_or(0),
            dev.map(|d| d.dense_calls).unwrap_or(0),
            dev.map(|d| d.dense_dev_calls).unwrap_or(0),
            dev.map(|d| d.dev_dispatches).unwrap_or(0),
            dev.map(|d| d.probs_bytes).unwrap_or(0),
            dev.map(|d| d.rehome_bytes).unwrap_or(0),
            dev.map(|d| d.blocks_live).unwrap_or(0),
            dev.map(|d| d.pad_slots).unwrap_or(0),
            host.ms,
            host.tokens,
            host.host_bytes,
            host.decode_ms,
            host.decode_bytes,
            host.dense_calls,
            host.probs_bytes,
            slow.ms,
            slow.tokens
        ));
    }
    // ── shared-prefix chat: cold vs warm prefill through the prefix
    // cache (DESIGN.md §Serving).  Two conversations share the CHAT
    // system prompt; the first request is cold, the second seeds its
    // shared prefix from the cache and must execute only its unshared
    // tail.  Requires the host extend path (the seed's staging target).
    let mut chat_json = String::from("null");
    let mut chat_spec = workload::CHAT;
    // fit the chat geometry to the artifact set: system prompt + one
    // jittered user turn must fit the largest compiled extend bucket
    // (the quick CI set has a single 512 bucket — the system prompt
    // shrinks to 384 there), and the system prompt must span at least
    // one prefix-cache block (≤ 128 tokens on either tier) so the cold
    // request actually registers an entry.
    let ext_lmax = mm
        .buckets("prefill_extend", "l_max")
        .iter()
        .copied()
        .max()
        .unwrap_or(0);
    let head_room = chat_spec.turn_len + chat_spec.jitter;
    if chat_spec.system_len + head_room > ext_lmax {
        chat_spec.system_len = ext_lmax.saturating_sub(head_room);
    }
    let sys = workload::chat_system_prompt(
        &chat_spec,
        mm.vocab_size,
        &mut Rng::new(0xC4A7),
    );
    let mut turn_rng = Rng::new(0x7EA);
    let user_a = workload::chat_user_turn(&chat_spec, mm.vocab_size, &mut turn_rng);
    let user_b = workload::chat_user_turn(&chat_spec, mm.vocab_size, &mut turn_rng);
    let prompt_a = workload::chat_turn_prompt(&sys, &[], &user_a);
    let prompt_b = workload::chat_turn_prompt(&sys, &[], &user_b);
    let longest = prompt_a.len().max(prompt_b.len());
    let can_chat = !mm.buckets("prefill_extend", "chunk").is_empty()
        && chat_spec.system_len >= 128
        && mm.bucket_for("prefill_extend", "l_max", longest).is_some();
    if can_chat {
        let mut cfg = base.clone();
        cfg.prefill_chunk = chunk;
        cfg.prefix_cache_blocks = 64;
        let mut engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
        let mut run_one = |prompt: &[i32]| -> anyhow::Result<(f64, u64, u64, u64, u64)> {
            let tok0 = engine.stats.prefill_tokens_executed;
            let hit0 = engine.stats.prefix_hit_tokens;
            let blk0 = engine.stats.prefix_hit_blocks;
            let rehome0 = engine.stats.kv_rehome_bytes;
            let mut seq = engine.new_sequence(0, prompt.to_vec());
            let t0 = Instant::now();
            while !engine.prefill_chunk(&mut seq, chunk)? {}
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            engine.release(&mut seq);
            Ok((
                ms,
                engine.stats.prefill_tokens_executed - tok0,
                engine.stats.prefix_hit_tokens - hit0,
                engine.stats.prefix_hit_blocks - blk0,
                engine.stats.kv_rehome_bytes - rehome0,
            ))
        };
        let (cold_ms, cold_tok, cold_hit, _, cold_rehome) = run_one(&prompt_a)?;
        let (warm_ms, warm_tok, warm_hit, warm_blk, warm_rehome) =
            run_one(&prompt_b)?;
        assert_eq!(cold_hit, 0, "first request must miss the prefix cache");
        assert!(warm_hit > 0, "second request must hit the shared prefix");
        assert_eq!(
            warm_tok,
            (prompt_b.len() as u64) - warm_hit,
            "warm prefill must execute exactly the unshared tail"
        );
        assert_eq!(cold_rehome, 0, "prefix path must not re-home KV");
        assert_eq!(warm_rehome, 0, "prefix path must not re-home KV");
        let (_, _, hits, misses, _) = engine.prefix_cache_stats();
        println!(
            "  chat: cold {} tok {cold_ms:.1} ms → warm {} tok {warm_ms:.1} ms \
             (hit {warm_hit} tok / {warm_blk} blocks; {hits} hits {misses} misses)",
            cold_tok, warm_tok
        );
        md.push_str(&format!(
            "\n### Shared-prefix chat (prefix cache)\n\n\
             | request | prompt | prefill_tokens_executed | prefix hit tok | prefix hit blocks | ttft ms | rehome KB |\n\
             |---|---|---|---|---|---|---|\n\
             | cold | {} | {cold_tok} | 0 | 0 | {cold_ms:.1} | {} |\n\
             | warm | {} | {warm_tok} | {warm_hit} | {warm_blk} | {warm_ms:.1} | {} |\n",
            prompt_a.len(),
            cold_rehome / 1024,
            prompt_b.len(),
            warm_rehome / 1024,
        ));
        chat_json = format!(
            "{{\"system_len\":{},\"cold_prompt\":{},\"cold_ttft_ms\":{cold_ms:.3},\
             \"cold_prefill_tokens_executed\":{cold_tok},\
             \"warm_prompt\":{},\"warm_ttft_ms\":{warm_ms:.3},\
             \"warm_prefill_tokens_executed\":{warm_tok},\
             \"prefix_hit_tokens\":{warm_hit},\"prefix_hit_blocks\":{warm_blk},\
             \"kv_rehome_bytes\":{warm_rehome}}}",
            sys.len(),
            prompt_a.len(),
            prompt_b.len(),
        );
    } else {
        println!(
            "  chat: skipped (extend buckets absent or too small for a \
             cached system prompt)"
        );
    }

    // ── overload smoke: a mixed-priority burst 3×-overcommitting a
    // capped paged device pool through the scheduler (DESIGN.md
    // §Overload).  Emits throughput + tail latency + the preemption /
    // swap economics columns, and asserts the graceful-degradation
    // invariants: zero failed requests, zero re-home bytes, and
    // suspend/restore conservation.
    let mut overload_json = String::from("null");
    let can_overload = has_paged
        && mm.bucket_for("layer_step", "batch", 3).is_some()
        && mm.bucket_for("layer_step_dense", "l_max", 256).is_some();
    if can_overload {
        use prhs::coordinator::overload::Priority;
        use prhs::coordinator::{RequestIn, Scheduler};

        let mut cfg = base.clone();
        cfg.max_batch = 3;
        // block 64: six 2-block requests against a 4-block cap
        cfg.device_block_cap = 4;
        let engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
        let mut sched = Scheduler::new(engine);
        let mut rng = Rng::new(0x0E71);
        let classes =
            [Priority::Low, Priority::Normal, Priority::High];
        let n_reqs = 6u64;
        for id in 0..n_reqs {
            sched.submit(RequestIn {
                id,
                prompt: (0..120)
                    .map(|_| rng.below(mm.vocab_size) as i32)
                    .collect(),
                max_new_tokens: 4,
                sampling: Default::default(),
                priority: Some(classes[id as usize % classes.len()]),
            });
        }
        let outs = sched.run_to_completion()?;
        let completed =
            outs.iter().filter(|o| o.rejected.is_none()).count();
        assert_eq!(
            completed,
            n_reqs as usize,
            "overload smoke: every request must complete"
        );
        let m = &mut sched.metrics;
        assert_eq!(
            m.kv_rehome_bytes, 0,
            "overload smoke: preemption must pre-empt re-homing"
        );
        assert_eq!(
            m.preemptions,
            m.restores_reseed + m.restores_restage,
            "overload smoke: every suspension must resume"
        );
        assert_eq!(m.swap_in_bytes, m.swap_out_bytes);
        assert_eq!(m.shed_requests, 0);
        let tput = m.throughput_tps();
        let ttft_p50 = m.ttft_lat.percentile_us(50.0) / 1e3;
        let ttft_p95 = m.ttft_lat.percentile_us(95.0) / 1e3;
        let step_p95 = m.step_lat.percentile_us(95.0) / 1e3;
        println!(
            "  overload: {completed}/{n_reqs} served at 3× block \
             overcommit, {} preemptions ({} reseed / {} restage), \
             {} pressure events, {tput:.1} tok/s, ttft p95 \
             {ttft_p95:.1} ms",
            m.preemptions,
            m.restores_reseed,
            m.restores_restage,
            m.kv_pressure_events
        );
        md.push_str(&format!(
            "\n### Overload (3× device-block overcommit, mixed priorities)\n\n\
             | requests | completed | shed | preemptions | reseed | restage | swap out KB | swap in KB | pressure events | rehome KB | tok/s | ttft p50 ms | ttft p95 ms | step p95 ms |\n\
             |---|---|---|---|---|---|---|---|---|---|---|---|---|---|\n\
             | {n_reqs} | {completed} | {} | {} | {} | {} | {} | {} | {} | {} | {tput:.1} | {ttft_p50:.1} | {ttft_p95:.1} | {step_p95:.1} |\n",
            m.shed_requests,
            m.preemptions,
            m.restores_reseed,
            m.restores_restage,
            m.swap_out_bytes / 1024,
            m.swap_in_bytes / 1024,
            m.kv_pressure_events,
            m.kv_rehome_bytes / 1024,
        ));
        overload_json = format!(
            "{{\"requests\":{n_reqs},\"completed\":{completed},\
             \"shed_requests\":{},\"preemptions\":{},\
             \"restores_reseed\":{},\"restores_restage\":{},\
             \"swap_out_bytes\":{},\"swap_in_bytes\":{},\
             \"kv_pressure_events\":{},\"kv_rehome_bytes\":{},\
             \"throughput_tps\":{tput:.3},\"ttft_p50_ms\":{ttft_p50:.3},\
             \"ttft_p95_ms\":{ttft_p95:.3},\"step_p95_ms\":{step_p95:.3}}}",
            m.shed_requests,
            m.preemptions,
            m.restores_reseed,
            m.restores_restage,
            m.swap_out_bytes,
            m.swap_in_bytes,
            m.kv_pressure_events,
            m.kv_rehome_bytes,
        );
    } else {
        println!(
            "  overload: skipped (paged stages or batch-3 buckets absent)"
        );
    }

    // ── quantized residency: the same prefill + short decode with the
    // host KV tier at f32 vs int8 (DESIGN.md §Quantized-Residency).  The
    // page count is identical in both modes, so the resident-bytes ratio
    // is exactly the row-byte ratio 4d/(d+4) — ≥ 3× at d ≥ 12 — and the
    // engine's `StepStats::kv_resident_bytes` gauge is computed through
    // the same pure `model::kv_bytes` model CI tracks here.
    let mut quant_json = String::from("null");
    {
        let l = lens[0];
        let can_decode = mm
            .bucket_for("layer_step_dense", "l_max", l + DECODE_STEPS)
            .is_some();
        let run_q = |quant: KvQuant| -> anyhow::Result<(f64, u64, u64, u64)> {
            let mut cfg = base.clone();
            cfg.kv_quant = quant;
            let mut engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
            let mut rng = Rng::new(0x1A78);
            let prompt: Vec<i32> =
                (0..l).map(|_| rng.below(mm.vocab_size) as i32).collect();
            let mut seq = engine.new_sequence(0, prompt);
            seq.max_new = DECODE_STEPS;
            let t0 = Instant::now();
            while !engine.prefill_chunk(&mut seq, chunk)? {}
            while can_decode && !seq.done {
                let mut g = [&mut seq];
                engine.decode_step(&mut g)?;
            }
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let toks = seq.cache.len() as u64;
            let out = (
                ms,
                toks,
                engine.stats.kv_resident_bytes,
                engine.stats.dequant_rows,
            );
            engine.release(&mut seq);
            Ok(out)
        };
        let (f_ms, f_toks, f_res, f_deq) = run_q(KvQuant::Off)?;
        let (q_ms, q_toks, q_res, q_deq) = run_q(KvQuant::Int8)?;
        assert_eq!(f_toks, q_toks, "precision must not change the context");
        assert_eq!(f_deq, 0, "f32 residency must never dequantize");
        assert!(
            f_res >= 3 * q_res,
            "int8 residency must be ≥3× smaller ({f_res} vs {q_res})"
        );
        let per_tok_f = f_res as f64 / f_toks.max(1) as f64;
        let per_tok_q = q_res as f64 / q_toks.max(1) as f64;
        let budget = 1u64 << 30;
        let (nl, nh, hd) = (mm.n_layers, mm.n_heads, mm.head_dim);
        let mc_f = kv_bytes::max_concurrent(budget, KvQuant::Off, nl, nh, hd, 4096);
        let mc_q = kv_bytes::max_concurrent(budget, KvQuant::Int8, nl, nh, hd, 4096);
        println!(
            "  quant: L {l} resident {} KB f32 → {} KB int8 \
             ({per_tok_f:.0} → {per_tok_q:.0} B/tok, {q_deq} rows \
             dequantized); 1 GiB @4k fits {mc_f} f32 / {mc_q} int8 seqs",
            f_res / 1024,
            q_res / 1024,
        );
        md.push_str(&format!(
            "\n### Quantized residency (host KV tier, L = {l})\n\n\
             | precision | prefill+decode ms | resident KB | B/token | dequant rows | max seqs @1 GiB, 4k tok |\n\
             |---|---|---|---|---|---|\n\
             | f32 | {f_ms:.1} | {} | {per_tok_f:.0} | {f_deq} | {mc_f} |\n\
             | int8 | {q_ms:.1} | {} | {per_tok_q:.0} | {q_deq} | {mc_q} |\n",
            f_res / 1024,
            q_res / 1024,
        ));
        quant_json = format!(
            "{{\"l\":{l},\"kv_resident_bytes_f32\":{f_res},\
             \"kv_resident_bytes_int8\":{q_res},\
             \"resident_bytes_per_token_f32\":{per_tok_f:.1},\
             \"resident_bytes_per_token_int8\":{per_tok_q:.1},\
             \"bytes_ratio\":{:.4},\"dequant_rows_int8\":{q_deq},\
             \"max_concurrent_f32_1gib_4k\":{mc_f},\
             \"max_concurrent_int8_1gib_4k\":{mc_q}}}",
            f_res as f64 / q_res.max(1) as f64,
        );
    }

    md.push_str(
        "\nDev/host tokens grow linearly in L (recompute grows with the sum \
         of prefixes); dev prefill host-bytes grow O(chunk) per chunk + one \
         state download, and dev *decode* host-bytes stay O(N_sel + probs \
         row) per step — the host-staged path re-ships the context tile \
         every prefill chunk AND every dense/retrieval decode call \
         (DESIGN.md §2/§6a).  With paged artifacts the dev columns also \
         pin the pool invariants: rehome KB = 0 (growth is allocation, \
         never copy), blocks live = Θ(live tokens / block), and pad \
         slots = 0 (no whole-tile group padding — the paged layout's \
         waste is bounded by block − 1 rows per sequence).\n",
    );
    std::fs::create_dir_all("results")?;
    std::fs::write("results/prefill_scaling.md", &md)?;
    println!("→ results/prefill_scaling.md");
    if let Some(path) = json_path {
        let json = format!(
            "{{\"bench\":\"prefill_scaling\",\"chunk\":{chunk},\"rows\":[{}],\
             \"chat\":{chat_json},\"overload\":{overload_json},\
             \"quant\":{quant_json}}}\n",
            json_rows.join(",")
        );
        std::fs::write(&path, json)?;
        println!("→ {path}");
    }
    Ok(())
}
