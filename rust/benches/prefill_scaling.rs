//! Chunked-prefill scaling bench (tentpole regressions): total prefill
//! *compute* must scale with L, not with the sum of prefixes, and with
//! the device-resident KV path the *host bytes staged* per chunk must be
//! O(chunk), not ∝ start.
//!
//! For each prompt length L the bench runs a full chunked prefill on
//! three paths — device-resident (`prefill_extend_dev`, the default),
//! host-staged KV-in (`device_prefill_kv = false`), and the
//! prefix-recompute parity oracle (`EngineConfig::prefill_recompute`) —
//! reporting wall time, the engine's executed-prompt-token counter, and
//! the `StepStats::prefill_host_bytes_staged` counter.  Executed tokens
//! are the Θ(L)-vs-Θ(L²/chunk) compute signal; host bytes are the
//! bandwidth-collapse signal (DESIGN.md §6a).  CI compiles this via
//! `cargo bench --no-run` and runs it in the bench-smoke job with
//! `--quick --json results/prefill_scaling.json` (the `BENCH_ci.json`
//! artifact); running it requires `make artifacts`.

use prhs::config::{EngineConfig, SelectorKind};
use prhs::model::{ChunkLedger, Engine};
use prhs::runtime::{Runtime, WeightStore};
use prhs::util::bench::arg_value;
use prhs::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy)]
struct PathRun {
    ms: f64,
    tokens: u64,
    host_bytes: u64,
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("PRHS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built at {dir}");
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let json_path = arg_value("--json");
    let chunk = 128usize;
    let lens: &[usize] = if quick { &[256, 512] } else { &[512, 1024, 2048] };

    let mut base = EngineConfig::default();
    base.artifacts_dir = dir;
    base.selector.kind = SelectorKind::Cis;
    let rt = Arc::new(Runtime::new(&base.artifacts_dir)?);
    let mm = rt.model("small")?.clone();
    let ws = Arc::new(WeightStore::load(&rt, &mm)?);
    let has_dev = !mm.buckets("prefill_extend_dev", "chunk").is_empty();

    println!("== chunked-prefill scaling (chunk {chunk}) ==");
    let mut md = String::from(
        "## Chunked-prefill scaling — device-resident vs host-staged vs recompute\n\n\
         | L | dev ms | dev KB staged | host ms | host KB staged | recompute ms | recompute tokens |\n\
         |---|---|---|---|---|---|---|\n",
    );
    let mut json_rows: Vec<String> = Vec::new();
    for &l in lens {
        let run = |device: bool, recompute: bool| -> anyhow::Result<PathRun> {
            let mut cfg = base.clone();
            cfg.device_prefill_kv = device;
            cfg.prefill_recompute = recompute;
            let mut engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
            let mut rng = Rng::new(0x5CA1E);
            let prompt: Vec<i32> =
                (0..l).map(|_| rng.below(mm.vocab_size) as i32).collect();
            let mut seq = engine.new_sequence(0, prompt);
            seq.max_new = 1;
            let t0 = Instant::now();
            while !engine.prefill_chunk(&mut seq, chunk)? {}
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let out = PathRun {
                ms,
                tokens: engine.stats.prefill_tokens_executed,
                host_bytes: engine.stats.prefill_host_bytes_staged,
            };
            engine.release(&mut seq);
            Ok(out)
        };
        let dev = if has_dev { Some(run(true, false)?) } else { None };
        let host = run(false, false)?;
        let slow = run(false, true)?;
        assert_eq!(
            host.tokens,
            ChunkLedger::executed_tokens(l, chunk, true),
            "KV-in counter must be Θ(L)"
        );
        assert_eq!(
            slow.tokens,
            ChunkLedger::executed_tokens(l, chunk, false),
            "recompute counter must be Θ(L²/chunk)"
        );
        if let Some(d) = dev {
            assert_eq!(d.tokens, host.tokens, "device path is Θ(L) too");
            assert!(
                d.host_bytes < host.host_bytes,
                "device path must stage fewer host bytes"
            );
        }
        let (dev_ms, dev_kb) = dev
            .map(|d| (d.ms, d.host_bytes / 1024))
            .unwrap_or((f64::NAN, 0));
        println!(
            "  L {l:5}: dev {dev_ms:8.1} ms / {dev_kb:7} KB   \
             host {:8.1} ms / {:7} KB   recompute {:8.1} ms / {:6} tok",
            host.ms,
            host.host_bytes / 1024,
            slow.ms,
            slow.tokens,
        );
        md.push_str(&format!(
            "| {l} | {dev_ms:.1} | {dev_kb} | {:.1} | {} | {:.1} | {} |\n",
            host.ms,
            host.host_bytes / 1024,
            slow.ms,
            slow.tokens
        ));
        json_rows.push(format!(
            "{{\"l\":{l},\"chunk\":{chunk},\
             \"dev_ms\":{:.3},\"dev_tokens\":{},\"dev_host_bytes\":{},\
             \"host_ms\":{:.3},\"host_tokens\":{},\"host_host_bytes\":{},\
             \"recompute_ms\":{:.3},\"recompute_tokens\":{}}}",
            dev.map(|d| d.ms).unwrap_or(-1.0),
            dev.map(|d| d.tokens).unwrap_or(0),
            dev.map(|d| d.host_bytes).unwrap_or(0),
            host.ms,
            host.tokens,
            host.host_bytes,
            slow.ms,
            slow.tokens
        ));
    }
    md.push_str(
        "\nDev/host tokens grow linearly in L (recompute grows with the sum \
         of prefixes); dev host-bytes grow O(chunk) per chunk + one state \
         download, while the host-staged path re-ships the context tile \
         every chunk (DESIGN.md §6a).\n",
    );
    std::fs::create_dir_all("results")?;
    std::fs::write("results/prefill_scaling.md", &md)?;
    println!("→ results/prefill_scaling.md");
    if let Some(path) = json_path {
        let json = format!(
            "{{\"bench\":\"prefill_scaling\",\"chunk\":{chunk},\"rows\":[{}]}}\n",
            json_rows.join(",")
        );
        std::fs::write(&path, json)?;
        println!("→ {path}");
    }
    Ok(())
}
