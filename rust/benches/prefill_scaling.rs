//! Chunked-prefill scaling bench (issue tentpole regression): total
//! prefill work must scale with L, not with the sum of prefixes.
//!
//! For each prompt length L the bench runs a full chunked prefill on the
//! KV-in `prefill_extend` path and on the prefix-recompute parity-oracle
//! path (`EngineConfig::prefill_recompute`), reporting wall time and the
//! engine's executed-prompt-token counter.  The counter column is the
//! regression signal: Θ(L) for KV-in, Θ(L²/chunk) for recompute
//! (`ChunkLedger::executed_tokens`, DESIGN.md §6a).  CI compiles this via
//! `cargo bench --no-run`; running it requires `make artifacts`.

use prhs::config::{EngineConfig, SelectorKind};
use prhs::model::{ChunkLedger, Engine};
use prhs::runtime::{Runtime, WeightStore};
use prhs::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("PRHS_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built at {dir}");
        return Ok(());
    }
    let quick = std::env::args().any(|a| a == "--quick");
    let chunk = 128usize;
    let lens: &[usize] = if quick { &[256, 512] } else { &[512, 1024, 2048] };

    let mut base = EngineConfig::default();
    base.artifacts_dir = dir;
    base.selector.kind = SelectorKind::Cis;
    let rt = Arc::new(Runtime::new(&base.artifacts_dir)?);
    let mm = rt.model("small")?.clone();
    let ws = Arc::new(WeightStore::load(&rt, &mm)?);

    println!("== chunked-prefill scaling (chunk {chunk}) ==");
    let mut md = String::from(
        "## Chunked-prefill scaling — KV-in extend vs prefix recompute\n\n\
         | L | extend ms | extend tokens | recompute ms | recompute tokens | token ratio |\n\
         |---|---|---|---|---|---|\n",
    );
    for &l in lens {
        let run = |recompute: bool| -> anyhow::Result<(f64, u64)> {
            let mut cfg = base.clone();
            cfg.prefill_recompute = recompute;
            let mut engine = Engine::with_shared(rt.clone(), ws.clone(), cfg);
            let mut rng = Rng::new(0x5CA1E);
            let prompt: Vec<i32> =
                (0..l).map(|_| rng.below(mm.vocab_size) as i32).collect();
            let mut seq = engine.new_sequence(0, prompt);
            seq.max_new = 1;
            let t0 = Instant::now();
            while !engine.prefill_chunk(&mut seq, chunk)? {}
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let executed = engine.stats.prefill_tokens_executed;
            engine.release(&mut seq);
            Ok((ms, executed))
        };
        let (fast_ms, fast_tok) = run(false)?;
        let (slow_ms, slow_tok) = run(true)?;
        assert_eq!(
            fast_tok,
            ChunkLedger::executed_tokens(l, chunk, true),
            "KV-in counter must be Θ(L)"
        );
        assert_eq!(
            slow_tok,
            ChunkLedger::executed_tokens(l, chunk, false),
            "recompute counter must be Θ(L²/chunk)"
        );
        let ratio = slow_tok as f64 / fast_tok as f64;
        println!(
            "  L {l:5}: extend {fast_ms:8.1} ms / {fast_tok:6} tok   \
             recompute {slow_ms:8.1} ms / {slow_tok:6} tok   ({ratio:.2}x tokens)"
        );
        md.push_str(&format!(
            "| {l} | {fast_ms:.1} | {fast_tok} | {slow_ms:.1} | {slow_tok} | {ratio:.2} |\n"
        ));
    }
    md.push_str(
        "\nExtend tokens grow linearly in L; recompute tokens grow with the \
         sum of prefixes (the quadratic cost the KV-in artifact removes).\n",
    );
    std::fs::create_dir_all("results")?;
    std::fs::write("results/prefill_scaling.md", md)?;
    println!("→ results/prefill_scaling.md");
    Ok(())
}
