//! Micro-benchmarks of the L3 hot path pieces (perf-pass instrumentation,
//! EXPERIMENTS.md §Perf): gather staging, selector planning, host query
//! projection, top-k selection, JSON parse, dense-export staging, and the
//! batched-decode planning stage (serial vs planner pool).

use prhs::config::{SelectorConfig, SelectorKind};
use prhs::kvcache::{dequantize_row, quantize_row, KvQuant, PagePool, SeqKvCache};
use prhs::model::{kv_bytes, proj, Sequence};
use prhs::selector::{self, PlanKind, SelectorCtx};
use prhs::util::bench::{arg_value, Bencher, Report};
use prhs::util::fx;
use prhs::util::json::Json;
use prhs::util::pool::for_each_unit;
use prhs::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut report = Report::new("L3 hot-path micro-benchmarks");
    let mut rng = Rng::new(0xF00D);

    // --- KV gather staging: 8 heads x 160 indices x d32 -----------------
    let (h, d, l) = (8usize, 32usize, 4096usize);
    let mut pool = PagePool::new(h, d, 128);
    let mut cache = SeqKvCache::new(1);
    let row: Vec<f32> = (0..h * d).map(|_| rng.normal()).collect();
    for _ in 0..l {
        cache.append(&mut pool, 0, &row, &row).unwrap();
        cache.commit_token();
    }
    let idx: Vec<usize> = (0..160).map(|i| (i * 25) % l).collect();
    let mut out_k = vec![0f32; 160 * d];
    let mut out_v = vec![0f32; 160 * d];
    report.push(b.run("gather 8h x 160 x d32", || {
        for head in 0..h {
            cache.gather(&pool, 0, head, &idx, &mut out_k, &mut out_v);
        }
        std::hint::black_box(&out_k);
    }));

    // --- dense export (the retrieval-path staging, L = 4096) ------------
    let mut dk = vec![0f32; h * l * d];
    let mut dv = vec![0f32; h * l * d];
    report.push(b.run("export_dense 8h x 4096 x d32", || {
        cache.export_dense(&pool, 0, l, &mut dk, &mut dv);
        std::hint::black_box(&dk);
    }));

    // --- host query projection (dm=256 -> 8 x d32 + rope) ---------------
    let dm = 256;
    let hidden: Vec<f32> = (0..dm).map(|_| rng.normal()).collect();
    let norm = vec![1.0f32; dm];
    let wq: Vec<f32> = (0..dm * h * d).map(|_| rng.normal() * 0.05).collect();
    report.push(b.run("project_queries dm256 -> 8 x d32", || {
        let q = proj::project_queries(&hidden, &norm, &wq, h, d, 1234, 1e4, 1e-5);
        std::hint::black_box(q);
    }));

    // --- selector planning (CIS, 8 heads, seeded) ------------------------
    let cfg = SelectorConfig { kind: SelectorKind::Cis, ..Default::default() };
    let mut sel = selector::build(&cfg, 1, h, d);
    let probs: Vec<f32> = {
        let mut p: Vec<f32> = (0..2049).map(|_| rng.f32()).collect();
        let s: f32 = p.iter().sum();
        p.iter_mut().for_each(|x| *x /= s);
        p
    };
    for head in 0..h {
        sel.observe_probs(0, head, 2048, &probs);
    }
    let qs: Vec<Vec<f32>> = (0..h)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    let t = 2048usize;
    report.push(b.run("cis plan+sets 8 heads @2k ctx", || {
        let ctx = SelectorCtx {
            t,
            q_heads: &qs,
            q_heads_raw: &qs,
            hidden: &hidden,
            last_keys: None,
        };
        let p = sel.plan(0, &ctx);
        if let PlanKind::Retrieve { heads } = p {
            for (head, r) in heads.iter().enumerate() {
                if *r {
                    sel.observe_probs(0, head, t, &probs);
                }
            }
        }
        std::hint::black_box(sel.sets(0));
    }));

    // --- batched decode planning: serial vs planner pool -----------------
    // Mirrors the engine's per-layer host stage for a continuous batch of
    // 8 sequences at 2k context: query projection + selector planning +
    // selected-set gather staging into per-sequence slices.  This is the
    // work `EngineConfig::planner_threads` fans out while PJRT execution
    // stays on the engine thread.
    {
        let n_seq = 8usize;
        let n_sel = 256usize;
        let ctx_len = 2048usize;
        let mut bpool = PagePool::new(h, d, 128);
        let krow: Vec<f32> = (0..h * d).map(|_| rng.normal()).collect();
        let mut seqs: Vec<Sequence> = (0..n_seq)
            .map(|i| {
                let sel = selector::build(&cfg, 1, h, d);
                let mut s = Sequence::new(i as u64, Vec::new(), sel, 1, 8);
                for _ in 0..ctx_len {
                    s.cache.append(&mut bpool, 0, &krow, &krow).unwrap();
                    s.cache.commit_token();
                }
                for head in 0..h {
                    s.selector.observe_probs(0, head, ctx_len, &probs);
                }
                s
            })
            .collect();
        let hiddens: Vec<f32> =
            (0..n_seq * dm).map(|_| rng.normal()).collect();
        let mut ks = vec![0f32; n_seq * h * n_sel * d];
        let mut vs = vec![0f32; n_seq * h * n_sel * d];

        let run_stage = |threads: usize,
                         seqs: &mut [Sequence],
                         ks: &mut [f32],
                         vs: &mut [f32]| {
            let per = h * n_sel * d;
            let mut units: Vec<(&mut Sequence, &[f32], &mut [f32], &mut [f32])> =
                seqs.iter_mut()
                    .zip(hiddens.chunks(dm))
                    .zip(ks.chunks_mut(per))
                    .zip(vs.chunks_mut(per))
                    .map(|(((s, hid), k2), v2)| (s, hid, k2, v2))
                    .collect();
            let bpool = &bpool;
            let norm = &norm;
            let wq = &wq;
            for_each_unit(threads, &mut units, |(seq, hid, k2, v2)| {
                let hid: &[f32] = *hid;
                let t = seq.cache.len();
                // the shipped planning path: per-sequence PlanScratch,
                // allocation-free after warmup
                let Sequence { cache, selector, scratch, .. } = &mut **seq;
                scratch.project(hid, norm, wq, h, d, t);
                let pctx = SelectorCtx {
                    t,
                    q_heads: scratch.q_heads(),
                    q_heads_raw: scratch.q_raw(),
                    hidden: hid,
                    last_keys: None,
                };
                let _ = selector.plan(0, &pctx);
                for head in 0..h {
                    let set = &selector.sets(0)[head];
                    let off = head * n_sel * d;
                    let sl = set.len().min(n_sel);
                    cache.gather(
                        bpool,
                        0,
                        head,
                        &set[..sl],
                        &mut k2[off..off + sl * d],
                        &mut v2[off..off + sl * d],
                    );
                }
                std::hint::black_box(&k2[..d]);
            });
        };

        let m_serial = b.run("batched plan+stage 8 seqs serial", || {
            run_stage(1, &mut seqs, &mut ks, &mut vs);
        });
        let m_pool = b.run("batched plan+stage 8 seqs pool x4", || {
            run_stage(4, &mut seqs, &mut ks, &mut vs);
        });
        println!(
            "  planner-pool speedup over serial: {:.2}x",
            m_serial.mean_ns / m_pool.mean_ns.max(1.0)
        );
        report.push(m_serial);
        report.push(m_pool);
    }

    // --- int8 residency codec + selector-sketch fidelity ------------------
    // Row codec throughput (the per-append / per-read cost the quantized
    // pool adds), plus an engine-free measure of how much of the exact
    // f32 top-n_sel set a selector scoring against the int8 sketch keeps
    // (DESIGN.md §Quantized-Residency) — exported into the CI `quant`
    // object below.
    let krow_q: Vec<f32> = (0..d).map(|_| rng.normal() * 2.0).collect();
    let mut q8 = vec![0i8; d];
    let mut deq = vec![0f32; d];
    let s_q = quantize_row(&krow_q, &mut q8);
    report.push(b.run("quantize_row d32", || {
        let mut q = [0i8; 32];
        std::hint::black_box(quantize_row(&krow_q, &mut q));
    }));
    report.push(b.run("dequantize_row d32", || {
        dequantize_row(&q8, s_q, &mut deq);
        std::hint::black_box(&deq);
    }));
    let sketch_overlap = {
        let t_q = 2048usize;
        let n_sel_q = 256usize;
        let qv: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let mut exact = vec![0f32; t_q];
        let mut sketch = vec![0f32; t_q];
        let mut kq = vec![0i8; d];
        let mut khat = vec![0f32; d];
        for i in 0..t_q {
            let k: Vec<f32> = (0..d).map(|_| rng.normal() * 2.0).collect();
            let s = quantize_row(&k, &mut kq);
            dequantize_row(&kq, s, &mut khat);
            let (mut ze, mut zs) = (0f32, 0f32);
            for j in 0..d {
                ze += qv[j] * k[j];
                zs += qv[j] * khat[j];
            }
            exact[i] = ze;
            sketch[i] = zs;
        }
        let want: std::collections::HashSet<usize> =
            fx::top_k_indices(&exact, n_sel_q).into_iter().collect();
        let got = fx::top_k_indices(&sketch, n_sel_q);
        let hit = got.iter().filter(|i| want.contains(i)).count();
        let overlap = hit as f64 / n_sel_q as f64;
        println!("  int8 sketch top-{n_sel_q} overlap vs f32: {overlap:.4}");
        overlap
    };

    // --- top-k over a 4k row ---------------------------------------------
    let row4k: Vec<f32> = (0..4096).map(|_| rng.f32()).collect();
    report.push(b.run("top_k 88 of 4096", || {
        std::hint::black_box(fx::top_k_indices(&row4k, 88));
    }));

    // --- manifest JSON parse ---------------------------------------------
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        report.push(b.run("parse manifest.json", || {
            std::hint::black_box(Json::parse(&text).unwrap());
        }));
    }

    report.save("results", "micro_hotpath")?;
    if let Some(path) = arg_value("--json") {
        // machine-readable counters for the CI perf artifact
        // (BENCH_ci.json): the "batched plan+stage" rows are the plan-µs
        // signal the bench trajectory tracks, and `decode_staging` is
        // the engine-free byte model of one decode retrieval at the
        // small-model geometry (the same `model::decode_staging`
        // functions the engine's `decode_host_bytes_staged` counter is
        // computed through) — host-vs-device columns CI can track
        // without artifacts.
        use prhs::model::decode_staging as ds;
        let (nl, dmod, l2k, sb, ntop) =
            (4usize, 256usize, 2048usize, 8usize, 160usize);
        // paged-pool geometry at the same small-model scale: the table
        // term a paged dense call adds over the tile batch call, and
        // the allocation-only growth costs the paged columns track
        let (blk, mb) = (32usize, 2048usize / 32);
        let staging = format!(
            "{{\"l_max\":{l2k},\"n_sel\":160,\"batched\":{sb},\
             \"n_top\":{ntop},\"block\":{blk},\
             \"dense_host_call_bytes\":{},\"dense_dev_call_bytes\":{},\
             \"dense_dev_batch_call_bytes\":{},\
             \"dense_dev_paged_call_bytes\":{},\
             \"probs_row_bytes\":{},\"probs_topk_bytes\":{},\
             \"append_dev_bytes\":{},\"append_dev_batch_bytes\":{},\
             \"append_dev_paged_bytes\":{},\
             \"mirror_seed_bytes\":{},\"paged_seed_bytes\":{},\
             \"paged_handoff_bytes\":{},\
             \"prefix_seed_bytes\":{},\
             \"sparse_call_bytes\":{}}}",
            ds::dense_host_call_bytes(1, h, h, d, dmod, l2k, true),
            ds::dense_dev_call_bytes(dmod, h, h, d, l2k, true),
            ds::dense_dev_batch_call_bytes(sb, dmod, h, d),
            ds::dense_dev_paged_call_bytes(sb, dmod, h, d, mb),
            ds::probs_row_bytes(sb, h, l2k),
            ds::probs_topk_bytes(sb, h, ntop),
            ds::append_dev_bytes(nl, h, d),
            ds::append_dev_batch_bytes(sb, nl, h, d),
            ds::append_dev_paged_bytes(sb, nl, h, d),
            ds::mirror_seed_bytes(nl, h, l2k, d),
            ds::paged_seed_bytes(nl, h, l2k, d, mb),
            ds::paged_handoff_bytes(mb),
            // host seed cost of a prefix-cache hit covering half the 2k
            // context (the shared-prefix chat profile's system prompt)
            prhs::model::prefill_staging::prefix_seed_bytes(nl, h, d, l2k / 2),
            ds::sparse_call_bytes(1, h, h, d, dmod, 160, false),
        );
        // quantized-residency byte model at the same small-model geometry
        // (engine-free: pure `model::kv_bytes`), plus the measured sketch
        // fidelity — the max-concurrent-at-fixed-quality columns CI tracks
        let ptb_f32 = kv_bytes::per_token_bytes(KvQuant::Off, nl, h, d);
        let ptb_int8 = kv_bytes::per_token_bytes(KvQuant::Int8, nl, h, d);
        let budget = 1u64 << 30; // 1 GiB host residency budget
        let quant = format!(
            "{{\"per_token_bytes_f32\":{ptb_f32},\
             \"per_token_bytes_int8\":{ptb_int8},\
             \"bytes_ratio\":{:.4},\
             \"max_concurrent_f32_1gib_4k\":{},\
             \"max_concurrent_int8_1gib_4k\":{},\
             \"sketch_overlap_top256\":{sketch_overlap:.4}}}",
            ptb_f32 as f64 / ptb_int8 as f64,
            kv_bytes::max_concurrent(budget, KvQuant::Off, nl, h, d, 4096),
            kv_bytes::max_concurrent(budget, KvQuant::Int8, nl, h, d, 4096),
        );
        let json = format!(
            "{{\"report\":{},\"decode_staging\":{staging},\"quant\":{quant}}}\n",
            report.to_json().trim_end()
        );
        std::fs::write(&path, json)?;
        println!("→ {path}");
    }
    Ok(())
}
